//! Appendix F.2 (Figure 5): sensitivity to the convergence tolerance.
//! ε ∈ {10⁻³, 10⁻⁴, 10⁻⁵, 10⁻⁶} on the appendix design, both losses,
//! four methods.

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let tols = [1e-3, 1e-4, 1e-5, 1e-6];
    let (n, p, s) = cfg.appendix_dim();
    struct Cell {
        loss: Loss,
        eps: f64,
        kind: ScreeningKind,
        rep: u64,
    }
    let mut cells = Vec::new();
    for loss in [Loss::Gaussian, Loss::Logistic] {
        for &eps in &tols {
            for kind in main_methods() {
                for rep in 0..cfg.reps as u64 {
                    cells.push(Cell {
                        loss,
                        eps,
                        kind,
                        rep,
                    });
                }
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig5", cells, |_, c| {
        let data = simulate(n, p, s, 0.4, 2.0, c.loss, cfg.cell_seed(2_000, c.rep));
        let mut settings = paper_settings();
        settings.cd.eps = c.eps;
        let (_, secs) = fit_timed(&data, c.kind, &settings);
        (c.loss, c.eps, c.kind, secs)
    });

    let mut table = Table::new(&["Loss", "eps", "Method", "Time (s)", "CI half"]);
    for loss in [Loss::Gaussian, Loss::Logistic] {
        for &eps in &tols {
            for kind in main_methods() {
                let times: Vec<f64> = results
                    .iter()
                    .filter(|(l, e, k, _)| *l == loss && *e == eps && *k == kind)
                    .map(|(_, _, _, t)| *t)
                    .collect();
                let sm = Summary::of(&times);
                table.row(vec![
                    format!("{loss:?}"),
                    format!("{eps:e}"),
                    kind.name().into(),
                    format!("{}", sig_figs(sm.mean, 3)),
                    format!("{}", sig_figs(sm.ci_half, 2)),
                ]);
            }
        }
    }
    println!("\nFigure 5 — full-path time vs convergence tolerance");
    println!("{}", table.render());
    write_csv(cfg, "fig5_tolerance", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hessian_lead_survives_tight_tolerance() {
        // F.2's point: the gap between Hessian and the rest never
        // disappears as ε tightens.
        let data = simulate(60, 800, 5, 0.4, 2.0, Loss::Gaussian, 5);
        let mut tight = paper_settings();
        tight.cd.eps = 1e-6;
        let (h, _) = fit_timed(&data, ScreeningKind::Hessian, &tight);
        let (w, _) = fit_timed(&data, ScreeningKind::Working, &tight);
        assert!(h.total_passes() <= w.total_passes() * 2);
        // both still converge to matching solutions
        let bh = h.beta_dense(h.lambdas.len() - 1, 800);
        let bw = w.beta_dense(h.lambdas.len().min(w.lambdas.len()) - 1, 800);
        let diff = bh
            .iter()
            .zip(&bw)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(diff < 1e-2, "solutions diverged: {diff}");
    }
}
