//! Bench: the appendix experiment suite — Figure 4 (path length),
//! Figure 5 (tolerance), Figure 6 (Gap-Safe augmentation), Figure 8
//! (safe rules), Figure 9 (γ), Figure 10 (ablation), Figure 11
//! (Poisson), Figures 12–14 (runtime breakdown).

use hessian_screening::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        reps: 2,
        ..Default::default()
    };
    for exp in ["fig4", "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12"] {
        eprintln!("=== {exp} ===");
        experiments::run_experiment(exp, &cfg).expect(exp);
    }
}
