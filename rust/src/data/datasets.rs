//! Simulated analogues of the paper's real data sets (§4.2, App. E).
//!
//! The originals are network downloads (LIBSVM / UCI / TCGA) that this
//! offline environment cannot fetch, and the largest would not fit the
//! session budget. Per the substitution policy in DESIGN.md §3 we build,
//! for each data set, a synthetic analogue that preserves the properties
//! the benchmark is sensitive to:
//!
//! * the *aspect* (n vs. p regime) — scaled by `scale` when the original
//!   is too large, with the scale factor recorded here;
//! * the storage class and fill (dense vs. sparse CSC with the paper's
//!   reported density);
//! * the response family (least-squares vs. logistic);
//! * a correlation structure chosen to mimic the data class
//!   (gene-expression → correlated blocks; tf-idf/text → sparse,
//!   near-orthogonal; dense tall sets → moderate equicorrelation).
//!
//! Relative method timings (the paper's Table 1/4 content) depend on
//! exactly these knobs; absolute seconds are not comparable and are not
//! claimed (EXPERIMENTS.md).

use super::synthetic::{CorrelationStructure, SyntheticSpec};
use super::Dataset;
use crate::loss::Loss;
use crate::rng::derive_seed;

/// Catalog entry describing a real data set and its simulated analogue.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper-reported shape.
    pub paper_n: usize,
    pub paper_p: usize,
    pub paper_density: f64,
    pub loss: Loss,
    /// Shape actually generated here.
    pub n: usize,
    pub p: usize,
    /// None → dense.
    pub density: Option<f64>,
    pub structure: CorrelationStructure,
    pub rho: f64,
    /// Number of planted signals.
    pub s: usize,
    pub snr: f64,
    /// Scale factor applied to (n, p) relative to the paper.
    pub scale_note: &'static str,
}

impl DatasetSpec {
    /// Generate the analogue with a seed derived from `rep`.
    pub fn generate(&self, rep: u64) -> Dataset {
        let seed = derive_seed(0xDA7A_5E7, rep ^ fnv(self.name));
        let mut spec = SyntheticSpec::new(self.n, self.p, self.s)
            .rho(self.rho)
            .snr(self.snr)
            .loss(self.loss)
            .structure(self.structure)
            .seed(seed);
        if let Some(d) = self.density {
            spec = spec.density(d);
        }
        if matches!(self.loss, Loss::Logistic) {
            // Keep class probabilities off the boundary.
            spec = spec.signal_scale(1.0 / (self.s as f64).sqrt().max(1.0));
        }
        let mut ds = spec.generate();
        ds.name = self.name.to_string();
        ds
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// The twelve analogues of Table 1 / Table 4, in the paper's order.
pub fn dataset_catalog() -> Vec<DatasetSpec> {
    use CorrelationStructure::*;
    vec![
        DatasetSpec {
            name: "bcTCGA",
            paper_n: 536,
            paper_p: 17_322,
            paper_density: 1.0,
            loss: Loss::Gaussian,
            n: 536,
            p: 17_322,
            density: None,
            structure: Block(100),
            rho: 0.6,
            s: 30,
            snr: 3.0,
            scale_note: "full size",
        },
        DatasetSpec {
            name: "e2006-log1p",
            paper_n: 16_087,
            paper_p: 4_272_227,
            paper_density: 1.4e-3,
            loss: Loss::Gaussian,
            n: 2_000,
            p: 200_000,
            density: Some(1.4e-3),
            structure: Equicorrelated,
            rho: 0.0,
            s: 40,
            snr: 2.0,
            scale_note: "n/8, p/21 (offline budget)",
        },
        DatasetSpec {
            name: "e2006-tfidf",
            paper_n: 16_087,
            paper_p: 150_360,
            paper_density: 8.3e-3,
            loss: Loss::Gaussian,
            n: 4_000,
            p: 40_000,
            density: Some(8.3e-3),
            structure: Equicorrelated,
            rho: 0.0,
            s: 30,
            snr: 2.0,
            scale_note: "n/4, p/3.8",
        },
        DatasetSpec {
            name: "scheetz",
            paper_n: 120,
            paper_p: 18_975,
            paper_density: 1.0,
            loss: Loss::Gaussian,
            n: 120,
            p: 18_975,
            density: None,
            structure: Block(150),
            rho: 0.5,
            s: 15,
            snr: 2.0,
            scale_note: "full size",
        },
        DatasetSpec {
            name: "YearPredictionMSD",
            paper_n: 463_715,
            paper_p: 90,
            paper_density: 1.0,
            loss: Loss::Gaussian,
            n: 100_000,
            p: 90,
            density: None,
            structure: Equicorrelated,
            rho: 0.3,
            s: 40,
            snr: 1.0,
            scale_note: "n/4.6",
        },
        DatasetSpec {
            name: "arcene",
            paper_n: 100,
            paper_p: 10_000,
            paper_density: 0.54,
            loss: Loss::Logistic,
            n: 100,
            p: 10_000,
            density: None, // 54% fill: dense storage wins
            structure: Block(50),
            rho: 0.5,
            s: 20,
            snr: 1.0,
            scale_note: "full size (dense storage; paper density 0.54)",
        },
        DatasetSpec {
            name: "colon-cancer",
            paper_n: 62,
            paper_p: 2_000,
            paper_density: 1.0,
            loss: Loss::Logistic,
            n: 62,
            p: 2_000,
            density: None,
            structure: Block(40),
            rho: 0.6,
            s: 10,
            snr: 1.0,
            scale_note: "full size",
        },
        DatasetSpec {
            name: "duke-breast-cancer",
            paper_n: 44,
            paper_p: 7_129,
            paper_density: 1.0,
            loss: Loss::Logistic,
            n: 44,
            p: 7_129,
            density: None,
            structure: Block(60),
            rho: 0.6,
            s: 8,
            snr: 1.0,
            scale_note: "full size",
        },
        DatasetSpec {
            name: "ijcnn1",
            paper_n: 35_000,
            paper_p: 22,
            paper_density: 1.0,
            loss: Loss::Logistic,
            n: 35_000,
            p: 22,
            density: None,
            structure: Equicorrelated,
            rho: 0.2,
            s: 12,
            snr: 1.0,
            scale_note: "full size",
        },
        DatasetSpec {
            name: "madelon",
            paper_n: 2_000,
            paper_p: 500,
            paper_density: 1.0,
            loss: Loss::Logistic,
            n: 2_000,
            p: 500,
            density: None,
            structure: Equicorrelated,
            rho: 0.7, // madelon is notoriously correlated/noisy
            s: 15,
            snr: 0.5,
            scale_note: "full size; high ρ to mimic madelon's redundancy",
        },
        DatasetSpec {
            name: "news20",
            paper_n: 19_996,
            paper_p: 1_355_191,
            paper_density: 3.4e-4,
            loss: Loss::Logistic,
            n: 4_000,
            p: 120_000,
            density: Some(3.4e-4),
            structure: Equicorrelated,
            rho: 0.0,
            s: 40,
            snr: 1.0,
            scale_note: "n/5, p/11",
        },
        DatasetSpec {
            name: "rcv1",
            paper_n: 20_242,
            paper_p: 47_236,
            paper_density: 1.6e-3,
            loss: Loss::Logistic,
            n: 5_000,
            p: 20_000,
            density: Some(1.6e-3),
            structure: Equicorrelated,
            rho: 0.0,
            s: 30,
            snr: 1.0,
            scale_note: "n/4, p/2.4",
        },
    ]
}

/// Look up a catalog entry by name (case-insensitive).
pub fn dataset_by_name(name: &str) -> Option<DatasetSpec> {
    dataset_catalog()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Design;

    #[test]
    fn catalog_has_all_twelve() {
        let cat = dataset_catalog();
        assert_eq!(cat.len(), 12);
        let ls = cat.iter().filter(|d| d.loss == Loss::Gaussian).count();
        assert_eq!(ls, 5, "five least-squares sets as in Table 1");
    }

    #[test]
    fn lookup_by_name() {
        assert!(dataset_by_name("colon-cancer").is_some());
        assert!(dataset_by_name("COLON-CANCER").is_some());
        assert!(dataset_by_name("no-such-set").is_none());
    }

    #[test]
    fn small_sets_generate_with_expected_shape() {
        let spec = dataset_by_name("colon-cancer").unwrap();
        let ds = spec.generate(0);
        assert_eq!(ds.n(), 62);
        assert_eq!(ds.p(), 2_000);
        assert_eq!(ds.loss, Loss::Logistic);
        assert!(ds.response.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn sparse_analogue_density_matches() {
        let spec = dataset_by_name("rcv1").unwrap();
        // shrink for test speed
        let small = DatasetSpec {
            n: 500,
            p: 2_000,
            ..spec
        };
        let ds = small.generate(1);
        assert!(ds.design.is_sparse());
        let d = ds.design.density();
        assert!((d - 1.6e-3).abs() < 6e-4, "density {d}");
    }

    #[test]
    fn reps_give_different_data_deterministically() {
        let spec = dataset_by_name("colon-cancer").unwrap();
        let a = spec.generate(0);
        let b = spec.generate(0);
        let c = spec.generate(1);
        assert_eq!(a.response, b.response);
        assert_ne!(a.response, c.response);
    }
}
