//! Integration: the whole path stack against independent oracles.
//!
//! * closed-form lasso solutions on the active set (Theorem 3.1);
//! * all screening strategies produce the same fits;
//! * property-based invariants over random problems (testkit).

use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::cholesky::Cholesky;
use hessian_screening::linalg::Design;
use hessian_screening::loss::Loss;
use hessian_screening::path::{PathFitter, PathSettings};
use hessian_screening::screening::ScreeningKind;
use hessian_screening::testkit::{forall, Config};

fn tight() -> PathSettings {
    let mut s = PathSettings::default();
    s.cd.eps = 1e-7;
    s.path_length = 25;
    s
}

/// Every step's solution must satisfy the stationarity conditions (2):
/// |c_j| ≤ λ for inactive, c_j = λ·sign(β_j) for active.
fn check_kkt(design: &DesignMatrix, y: &[f64], fit: &hessian_screening::path::PathFit, tol: f64) {
    let n = design.nrows();
    for k in 0..fit.lambdas.len() {
        let lambda = fit.lambdas[k];
        let mut eta = vec![0.0; n];
        for &(j, b) in &fit.betas[k] {
            design.col_axpy(j, b, &mut eta);
        }
        let mut resid = vec![0.0; n];
        fit.loss.pseudo_residual_into(y, &eta, &mut resid);
        let active: std::collections::HashMap<usize, f64> = fit.betas[k].iter().copied().collect();
        for j in 0..design.ncols() {
            let c = design.col_dot(j, &resid);
            match active.get(&j) {
                Some(&b) => assert!(
                    (c - lambda * b.signum()).abs() <= tol * lambda,
                    "step {k} active {j}: c={c} λ={lambda}"
                ),
                None => assert!(
                    c.abs() <= lambda * (1.0 + tol),
                    "step {k} inactive {j}: |c|={} > λ={lambda}",
                    c.abs()
                ),
            }
        }
    }
}

#[test]
fn gaussian_path_satisfies_kkt_all_strategies() {
    let data = SyntheticSpec::new(60, 120, 6).rho(0.5).snr(2.0).seed(1).generate();
    for kind in ScreeningKind::all() {
        if kind == ScreeningKind::Edpp && data.loss != Loss::Gaussian {
            continue;
        }
        let fit = PathFitter::new(Loss::Gaussian, kind)
            .with_settings(tight())
            .fit(&data.design, &data.response);
        check_kkt(&data.design, &data.response, &fit, 1e-2);
    }
}

#[test]
fn logistic_path_satisfies_kkt() {
    let data = SyntheticSpec::new(120, 60, 5)
        .loss(Loss::Logistic)
        .snr(2.0)
        .seed(2)
        .generate();
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working, ScreeningKind::Celer] {
        let mut s = tight();
        s.cd.eps = 1e-8;
        let fit = PathFitter::new(Loss::Logistic, kind)
            .with_settings(s)
            .fit(&data.design, &data.response);
        check_kkt(&data.design, &data.response, &fit, 5e-2);
    }
}

#[test]
fn closed_form_oracle_on_active_set_along_path() {
    // For the lasso, at every step: β_A = (X_AᵀX_A)⁻¹(X_Aᵀy − λ·sign).
    let data = SyntheticSpec::new(100, 30, 4).rho(0.3).snr(4.0).seed(3).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
        .with_settings(tight())
        .fit(&data.design, &data.response);
    for k in 1..fit.lambdas.len() {
        if fit.betas[k].is_empty() {
            continue;
        }
        let active: Vec<usize> = fit.betas[k].iter().map(|&(j, _)| j).collect();
        let xa = dense.select_cols(&active);
        let h = xa.t_gemm(&xa);
        let mut rhs = vec![0.0; active.len()];
        xa.t_gemv_dense(&data.response, &mut rhs);
        for (i, &(_, b)) in fit.betas[k].iter().enumerate() {
            rhs[i] -= fit.lambdas[k] * b.signum();
        }
        let oracle = Cholesky::factor(&h).unwrap().solve(&rhs);
        for (i, &(j, b)) in fit.betas[k].iter().enumerate() {
            assert!(
                (b - oracle[i]).abs() < 1e-4,
                "step {k} coef {j}: {b} vs oracle {}",
                oracle[i]
            );
        }
    }
}

#[test]
fn property_null_model_at_lambda_max_and_monotone_dev() {
    forall(Config { cases: 10, seed: 0xAB }, |g| {
        let n = g.usize_in(30, 80);
        let p = g.usize_in(10, 60);
        let s = g.usize_in(1, 5.min(p));
        let rho = g.choose(&[0.0, 0.3, 0.6]);
        let data = SyntheticSpec::new(n, p, s)
            .rho(rho)
            .snr(2.0)
            .seed(g.rng.next_u64())
            .generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        if !fit.betas[0].is_empty() {
            return Err("non-null model at λmax".into());
        }
        for w in fit.dev_ratios.windows(2) {
            if w[1] < w[0] - 1e-8 {
                return Err(format!("dev ratio decreased: {} -> {}", w[0], w[1]));
            }
        }
        for w in fit.lambdas.windows(2) {
            if w[1] >= w[0] {
                return Err("λ not strictly decreasing".into());
            }
        }
        Ok(())
    });
}

#[test]
fn property_strategies_agree_on_random_problems() {
    forall(Config { cases: 6, seed: 0xCD }, |g| {
        let n = g.usize_in(40, 70);
        let p = g.usize_in(30, 90);
        let data = SyntheticSpec::new(n, p, 4)
            .rho(g.choose(&[0.0, 0.5]))
            .snr(2.0)
            .seed(g.rng.next_u64())
            .generate();
        let a = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .with_settings(tight())
            .fit(&data.design, &data.response);
        let b = PathFitter::new(Loss::Gaussian, ScreeningKind::Strong)
            .with_settings(tight())
            .fit(&data.design, &data.response);
        let m = a.lambdas.len().min(b.lambdas.len());
        for k in 0..m {
            let ba = a.beta_dense(k, p);
            let bb = b.beta_dense(k, p);
            for j in 0..p {
                if (ba[j] - bb[j]).abs() > 5e-3 {
                    return Err(format!(
                        "step {k} coef {j}: hessian {} vs strong {}",
                        ba[j], bb[j]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_screened_set_contains_next_active_set() {
    // The *final* working set of a step must contain its active set
    // (by construction), and violations must stay rare for γ = 0.01.
    forall(Config { cases: 6, seed: 0xEF }, |g| {
        let data = SyntheticSpec::new(50, 300, 5)
            .rho(g.choose(&[0.4, 0.8]))
            .snr(2.0)
            .seed(g.rng.next_u64())
            .generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        for (k, st) in fit.steps.iter().enumerate() {
            if st.screened_final < st.active {
                return Err(format!(
                    "step {k}: final working set {} smaller than active {}",
                    st.screened_final, st.active
                ));
            }
        }
        let steps = fit.steps.len().max(1);
        let vio_rate = fit.total_violations() as f64 / steps as f64;
        if vio_rate > 2.0 {
            return Err(format!("violation rate {vio_rate} too high"));
        }
        Ok(())
    });
}

#[test]
fn elastic_net_path_runs_and_shrinks() {
    let data = SyntheticSpec::new(60, 40, 5).rho(0.3).snr(3.0).seed(9).generate();
    let mut plain = PathSettings::default();
    plain.path_length = 20;
    let mut enet = plain.clone();
    enet.cd.phi = 30.0;
    let a = PathFitter::new(Loss::Gaussian, ScreeningKind::Working)
        .with_settings(plain)
        .fit(&data.design, &data.response);
    let b = PathFitter::new(Loss::Gaussian, ScreeningKind::Working)
        .with_settings(enet)
        .fit(&data.design, &data.response);
    let ka = a.lambdas.len() - 1;
    let kb = b.lambdas.len() - 1;
    let l1a: f64 = a.betas[ka].iter().map(|(_, v)| v.abs()).sum();
    let l1b: f64 = b.betas[kb].iter().map(|(_, v)| v.abs()).sum();
    assert!(l1b < l1a, "elastic net must shrink: {l1b} vs {l1a}");
}

#[test]
fn failure_injection_duplicated_and_constant_columns() {
    // Appendix-C stress: duplicate columns make X_AᵀX_A exactly
    // singular; a constant column has zero variance. The preconditioned
    // Hessian tracker must keep the whole path finite and KKT-valid.
    use hessian_screening::linalg::DenseMatrix;
    let base = SyntheticSpec::new(60, 20, 3).snr(3.0).seed(77).generate();
    let dense = match &base.design {
        DesignMatrix::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let mut m = DenseMatrix::zeros(60, 23);
    for j in 0..20 {
        m.col_mut(j).copy_from_slice(dense.col(j));
    }
    // two exact duplicates of strong columns + one constant column
    let c0 = dense.col(0).to_vec();
    let c1 = dense.col(1).to_vec();
    m.col_mut(20).copy_from_slice(&c0);
    m.col_mut(21).copy_from_slice(&c1);
    // constant column (centered to zero by standardization convention;
    // here already centered data, so use literal zeros)
    for v in m.col_mut(22).iter_mut() {
        *v = 0.0;
    }
    let design = DesignMatrix::Dense(m);
    let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
        .fit(&design, &base.response);
    assert!(fit.lambdas.len() > 3);
    for k in 0..fit.lambdas.len() {
        for &(j, b) in &fit.betas[k] {
            assert!(b.is_finite(), "step {k} coef {j} not finite");
            assert_ne!(j, 22, "constant column must never activate");
        }
    }
    // Solutions still KKT-valid despite the singular Gram.
    check_kkt(&design, &base.response, &fit, 5e-2);
}

#[test]
fn failure_injection_extreme_lambda_grid() {
    // A grid that collapses almost to zero must not hang or produce
    // non-finite coefficients (stall guards + saturation stop).
    let data = SyntheticSpec::new(30, 100, 5).snr(1.0).seed(78).generate();
    let mut s = PathSettings::default();
    s.lambda_min_ratio = Some(1e-8);
    s.path_length = 120;
    let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
        .with_settings(s)
        .fit(&data.design, &data.response);
    for k in 0..fit.lambdas.len() {
        for &(_, b) in &fit.betas[k] {
            assert!(b.is_finite());
        }
    }
    // saturation stop: never more ever-active than min(n, p) + slack
    let max_active = fit.steps.iter().map(|s| s.active).max().unwrap();
    assert!(max_active <= 31, "active {max_active} exceeded saturation cap");
}
