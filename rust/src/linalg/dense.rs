//! Dense column-major matrix.
//!
//! Column-major is the right layout for pathwise coordinate descent:
//! every inner-loop primitive (`col_dot`, `col_axpy`) walks one
//! contiguous column, and the full correlation sweep Xᵀr is a sequence
//! of contiguous dot products.

use super::blas;
use super::Design;

/// Dense n×p matrix, column-major.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    nrows: usize,
    ncols: usize,
    /// data[j*nrows .. (j+1)*nrows] is column j.
    data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        Self { nrows, ncols, data }
    }

    /// Build from row-slices (each of length ncols).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let nrows = rows.len();
        let ncols = if nrows == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(nrows, ncols);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), ncols);
            for (j, &v) in r.iter().enumerate() {
                *m.at_mut(i, j) = v;
            }
        }
        m
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        // SAFETY: i < nrows and j < ncols (debug_assert; callers index by
        // matrix shape), so j*nrows + i <= (ncols-1)*nrows + nrows-1 <
        // nrows*ncols = data.len() (constructors enforce the length).
        unsafe { *self.data.get_unchecked(j * self.nrows + i) }
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        // SAFETY: same bound as `at`: j*nrows + i < nrows*ncols = data.len().
        unsafe { self.data.get_unchecked_mut(j * self.nrows + i) }
    }

    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// out ← A·v.
    pub fn gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.ncols);
        assert_eq!(out.len(), self.nrows);
        out.iter_mut().for_each(|o| *o = 0.0);
        for j in 0..self.ncols {
            blas::axpy(v[j], self.col(j), out);
        }
    }

    /// out ← Aᵀ·v.
    pub fn t_gemv_dense(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows);
        assert_eq!(out.len(), self.ncols);
        for j in 0..self.ncols {
            out[j] = blas::dot(self.col(j), v);
        }
    }

    /// C ← AᵀB (self = A, m×k result where self is n×m, other n×k).
    pub fn t_gemm(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.nrows, other.nrows);
        let mut c = DenseMatrix::zeros(self.ncols, other.ncols);
        for j in 0..other.ncols {
            let bj = other.col(j);
            for i in 0..self.ncols {
                *c.at_mut(i, j) = blas::dot(self.col(i), bj);
            }
        }
        c
    }

    /// C ← A·B.
    pub fn gemm(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.ncols, other.nrows);
        let mut c = DenseMatrix::zeros(self.nrows, other.ncols);
        for j in 0..other.ncols {
            let bj = other.col(j);
            let cj = c.col_mut(j);
            for (k, &bkj) in bj.iter().enumerate() {
                blas::axpy(bkj, self.col(k), cj);
            }
        }
        c
    }

    /// Transpose (fresh allocation).
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.ncols, self.nrows);
        for j in 0..self.ncols {
            for i in 0..self.nrows {
                *t.at_mut(j, i) = self.at(i, j);
            }
        }
        t
    }

    /// Extract the sub-matrix with the given columns (in order).
    pub fn select_cols(&self, cols: &[usize]) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.nrows, cols.len());
        for (jj, &j) in cols.iter().enumerate() {
            m.col_mut(jj).copy_from_slice(self.col(j));
        }
        m
    }

    /// Symmetric max |a_ij − b_ij|, for tests.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!(self.nrows, other.nrows);
        assert_eq!(self.ncols, other.ncols);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        blas::nrm2(&self.data)
    }
}

impl Design for DenseMatrix {
    fn nrows(&self) -> usize {
        self.nrows
    }

    fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        blas::dot(self.col(j), v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]) {
        blas::axpy(alpha, self.col(j), v);
    }

    #[inline]
    fn col_sq_norm(&self, j: usize) -> f64 {
        blas::sq_norm(self.col(j))
    }

    fn gram(&self, i: usize, j: usize) -> f64 {
        blas::dot(self.col(i), self.col(j))
    }

    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64 {
        match w {
            None => self.gram(i, j),
            Some(w) => blas::dot_w(self.col(i), self.col(j), w),
        }
    }

    fn density(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // [[1, 4], [2, 5], [3, 6]]
        DenseMatrix::from_col_major(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn indexing_and_cols() {
        let m = small();
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(2, 1), 6.0);
        assert_eq!(m.col(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn from_rows_matches_col_major() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 4.0], vec![2.0, 5.0], vec![3.0, 6.0]]);
        assert_eq!(m, small());
    }

    #[test]
    fn gemv_and_t_gemv() {
        let m = small();
        let mut out = vec![0.0; 3];
        m.gemv(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-3.0, -3.0, -3.0]);
        let mut c = vec![0.0; 2];
        m.t_gemv_dense(&[1.0, 0.0, 1.0], &mut c);
        assert_eq!(c, vec![4.0, 10.0]);
    }

    #[test]
    fn design_trait_ops() {
        let m = small();
        assert_eq!(m.col_dot(0, &[1.0, 1.0, 1.0]), 6.0);
        assert_eq!(m.col_sq_norm(1), 77.0);
        let mut v = vec![0.0; 3];
        m.col_axpy(0, 2.0, &mut v);
        assert_eq!(v, vec![2.0, 4.0, 6.0]);
        assert_eq!(m.gram(0, 1), 32.0);
        let w = vec![1.0, 0.0, 0.0];
        assert_eq!(m.gram_weighted(0, 1, Some(&w)), 4.0);
    }

    #[test]
    fn t_gemm_is_gram() {
        let m = small();
        let g = m.t_gemm(&m);
        assert_eq!(g.nrows(), 2);
        assert_eq!(g.at(0, 0), 14.0);
        assert_eq!(g.at(0, 1), 32.0);
        assert_eq!(g.at(1, 0), 32.0);
        assert_eq!(g.at(1, 1), 77.0);
    }

    #[test]
    fn gemm_identity() {
        let m = small();
        let i2 = DenseMatrix::identity(2);
        assert_eq!(m.gemm(&i2), m);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = small();
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(1, 2), 6.0);
    }

    #[test]
    fn select_cols_subset() {
        let m = small();
        let s = m.select_cols(&[1]);
        assert_eq!(s.ncols(), 1);
        assert_eq!(s.col(0), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn subset_gemv_via_design() {
        let m = small();
        let mut out = vec![0.0; 3];
        m.gemv_subset(&[1], &[2.0], &mut out);
        assert_eq!(out, vec![8.0, 10.0, 12.0]);
        let mut c = vec![0.0; 1];
        m.t_gemv_subset(&[1.0, 1.0, 1.0], &[0], &mut c);
        assert_eq!(c, vec![6.0]);
    }
}
