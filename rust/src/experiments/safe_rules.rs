//! Appendix F.6 (Figure 8): the safe rules the main paper omits —
//! EDPP, Gap Safe and Dynamic Sasvi — on the high-dimensional
//! least-squares scenario, with the Hessian rule as the reference.
//! (The paper found these "performed so poorly that we omit the
//! results"; the expected shape is a large gap to the Hessian rule.)

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let (n, p, s) = cfg.high_dim();
    let methods = [
        ScreeningKind::Hessian,
        ScreeningKind::GapSafe,
        ScreeningKind::Edpp,
        ScreeningKind::Sasvi,
    ];
    struct Cell {
        kind: ScreeningKind,
        rho: f64,
        rep: u64,
    }
    let mut cells = Vec::new();
    for &kind in &methods {
        for &rho in &[0.0, 0.4, 0.8] {
            for rep in 0..cfg.reps as u64 {
                cells.push(Cell { kind, rho, rep });
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig8", cells, |_, c| {
        let data = simulate(n, p, s, c.rho, 2.0, Loss::Gaussian, cfg.cell_seed(4_000, c.rep));
        let (fit, secs) = fit_timed(&data, c.kind, &paper_settings());
        (c.kind, c.rho, secs, fit.mean_screened())
    });

    let mut table = Table::new(&["Method", "rho", "Time (s)", "CI half", "Screened"]);
    for &kind in &methods {
        for &rho in &[0.0, 0.4, 0.8] {
            let rows: Vec<_> = results
                .iter()
                .filter(|(k, r, _, _)| *k == kind && *r == rho)
                .collect();
            let sm = Summary::of(&rows.iter().map(|r| r.2).collect::<Vec<_>>());
            let scr = rows.iter().map(|r| r.3).sum::<f64>() / rows.len().max(1) as f64;
            table.row(vec![
                kind.name().into(),
                format!("{rho}"),
                format!("{}", sig_figs(sm.mean, 3)),
                format!("{}", sig_figs(sm.ci_half, 2)),
                format!("{}", sig_figs(scr, 4)),
            ]);
        }
    }
    println!("\nFigure 8 — safe rules (EDPP / Gap Safe / Sasvi) vs Hessian");
    println!("{}", table.render());
    write_csv(cfg, "fig8_safe_rules", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safe_rules_screen_far_more_conservatively() {
        let data = simulate(50, 800, 5, 0.4, 2.0, Loss::Gaussian, 9);
        let (h, _) = fit_timed(&data, ScreeningKind::Hessian, &paper_settings());
        let (g, _) = fit_timed(&data, ScreeningKind::GapSafe, &paper_settings());
        let (sv, _) = fit_timed(&data, ScreeningKind::Sasvi, &paper_settings());
        assert!(h.mean_screened() < g.mean_screened());
        assert!(h.mean_screened() < sv.mean_screened());
    }
}
