//! Experiment coordinator: a work-stealing thread pool that runs the
//! benchmark grid (dataset × method × repetition cells) in parallel and
//! collects results in deterministic (submission) order.
//!
//! The offline image has no tokio/rayon, so this is built directly on
//! `std::thread::scope` + an atomic work counter: each worker claims the
//! next job index, runs it, and writes its slot — no locks on the hot
//! path, no ordering nondeterminism in the output. Timing-sensitive
//! benchmark cells set `threads = 1` (the harness runs repetition loops
//! sequentially inside a cell and parallelizes *across* cells only when
//! the cell declares itself parallel-safe).

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Thread-pool experiment runner.
#[derive(Clone, Copy, Debug)]
pub struct Coordinator {
    pub threads: usize,
}

impl Coordinator {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// One worker per available core, capped (leaving headroom for the
    /// leader thread and OS noise during timing runs).
    pub fn auto() -> Self {
        let n = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        Self::new(n.saturating_sub(1).clamp(1, 16))
    }

    /// Run `f` over `jobs`, returning results in job order. Panics in a
    /// job are propagated to the caller after all workers stop.
    pub fn run<J, R, F>(&self, jobs: Vec<J>, f: F) -> Vec<R>
    where
        J: Send + Sync,
        R: Send,
        F: Fn(usize, &J) -> R + Sync,
    {
        self.run_with(jobs, || (), |_, i, j| f(i, j))
    }

    /// Run `f` over `jobs` with one reusable per-worker state, built by
    /// `init` once per worker thread and threaded mutably through every
    /// job that worker claims. Results come back in job order.
    ///
    /// This is the cross-validation fold-loop surface: each fold worker
    /// gets one `path::Workspace` so consecutive fold fits on the same
    /// worker reuse the grown solver/sweep arenas instead of
    /// re-allocating them per fit. Per-worker state never moves between
    /// threads after `init`, so `S` only needs `Send` (for the scoped
    /// spawn), not `Sync`.
    pub fn run_with<J, R, S, I, F>(&self, jobs: Vec<J>, init: I, f: F) -> Vec<R>
    where
        J: Send + Sync,
        R: Send,
        S: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize, &J) -> R + Sync,
    {
        let njobs = jobs.len();
        if njobs == 0 {
            return Vec::new();
        }
        let threads = self.threads.min(njobs);
        if threads == 1 {
            let mut state = init();
            return jobs
                .iter()
                .enumerate()
                .map(|(i, j)| f(&mut state, i, j))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> = (0..njobs).map(|_| Mutex::new(None)).collect();
        let jobs_ref = &jobs;
        let f_ref = &f;
        let init_ref = &init;
        let slots_ref = &slots;
        let next_ref = &next;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..threads {
                handles.push(scope.spawn(move || {
                    let mut state = init_ref();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= njobs {
                            break;
                        }
                        let r = f_ref(&mut state, i, &jobs_ref[i]);
                        // Poison-proof: each slot is written by exactly one
                        // worker (the claimant of i) and `f` runs outside the
                        // lock, so a poisoned slot can only mean a worker
                        // panicked — which the join below re-throws anyway.
                        *slots_ref[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
                    }
                }));
            }
            for h in handles {
                h.join().expect("coordinator worker panicked");
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .expect("job not run")
            })
            .collect()
    }

    /// Run with a progress line on stderr (used by the `hx exp` CLI for
    /// long experiment grids).
    pub fn run_with_progress<J, R, F>(&self, label: &str, jobs: Vec<J>, f: F) -> Vec<R>
    where
        J: Send + Sync,
        R: Send,
        F: Fn(usize, &J) -> R + Sync,
    {
        let total = jobs.len();
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let out = self.run(jobs, |i, j| {
            let r = f(i, j);
            let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
            eprint!("\r  [{label}] {d}/{total} cells");
            r
        });
        eprintln!();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_job_order() {
        let c = Coordinator::new(4);
        let jobs: Vec<usize> = (0..100).collect();
        let out = c.run(jobs, |_, &j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_sequential() {
        let c = Coordinator::new(1);
        let order = Mutex::new(Vec::new());
        let out = c.run(vec![1, 2, 3], |i, &j| {
            order.lock().unwrap().push(i);
            j
        });
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn empty_jobs() {
        let c = Coordinator::auto();
        let out: Vec<i32> = c.run(Vec::<i32>::new(), |_, &j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let c = Coordinator::new(8);
        let counter = AtomicUsize::new(0);
        let out = c.run((0..257).collect::<Vec<_>>(), |_, &j| {
            counter.fetch_add(1, Ordering::Relaxed);
            j
        });
        assert_eq!(out.len(), 257);
        assert_eq!(counter.load(Ordering::Relaxed), 257);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn job_panics_propagate() {
        let c = Coordinator::new(2);
        let _ = c.run(vec![0, 1, 2, 3], |_, &j| {
            if j == 2 {
                panic!("boom");
            }
            j
        });
    }

    #[test]
    fn auto_has_at_least_one_thread() {
        assert!(Coordinator::auto().threads >= 1);
    }

    #[test]
    fn run_with_reuses_state_per_worker_serially() {
        // Serial path: one state instance sees every job in order.
        let c = Coordinator::new(1);
        let out = c.run_with(
            (0..5).collect::<Vec<usize>>(),
            Vec::<usize>::new,
            |seen, _, &j| {
                seen.push(j);
                seen.len()
            },
        );
        // Each job observed the accumulated state of its predecessors.
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn run_with_builds_one_state_per_worker() {
        let c = Coordinator::new(3);
        let inits = AtomicUsize::new(0);
        let out = c.run_with(
            (0..64).collect::<Vec<usize>>(),
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |count, _, &j| {
                *count += 1;
                j * 2
            },
        );
        assert_eq!(out, (0..64).map(|j| j * 2).collect::<Vec<_>>());
        // One init per worker thread, never one per job.
        let n = inits.load(Ordering::Relaxed);
        assert!((1..=3).contains(&n), "expected <= 3 inits, got {n}");
    }
}
