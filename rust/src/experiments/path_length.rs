//! Appendix F.1 (Figure 4): cost of increased path resolution. Fits
//! paths of length m ∈ {10, 20, 50, 100} on the appendix high-dim
//! design and the low-dim design, for the four main methods.

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let lengths = [10usize, 20, 50, 100];
    let scenarios: Vec<(&'static str, (usize, usize, usize), f64)> = vec![
        ("low-dim", cfg.low_dim(), 1.0),
        ("high-dim", cfg.appendix_dim(), 2.0),
    ];
    struct Cell {
        scenario: &'static str,
        m: usize,
        kind: ScreeningKind,
        rep: u64,
    }
    let mut cells = Vec::new();
    for (name, _, _) in &scenarios {
        for &m in &lengths {
            for kind in main_methods() {
                for rep in 0..cfg.reps as u64 {
                    cells.push(Cell {
                        scenario: name,
                        m,
                        kind,
                        rep,
                    });
                }
            }
        }
    }
    let dims: std::collections::HashMap<&str, ((usize, usize, usize), f64)> = scenarios
        .iter()
        .map(|(n, d, s)| (*n, (*d, *s)))
        .collect();
    let results = cfg.coordinator().run_with_progress("fig4", cells, |_, c| {
        let ((n, p, s), snr) = dims[c.scenario];
        let data = simulate(n, p, s, 0.4, snr, Loss::Gaussian, cfg.cell_seed(1_000, c.rep));
        let mut settings = paper_settings();
        settings.path_length = c.m;
        let (_, secs) = fit_timed(&data, c.kind, &settings);
        (c.scenario, c.m, c.kind, secs)
    });

    let mut table = Table::new(&["Scenario", "Path length", "Method", "Time (s)", "CI half"]);
    for (name, _, _) in &scenarios {
        for &m in &lengths {
            for kind in main_methods() {
                let times: Vec<f64> = results
                    .iter()
                    .filter(|(sc, mm, k, _)| *sc == *name && *mm == m && *k == kind)
                    .map(|(_, _, _, t)| *t)
                    .collect();
                let s = Summary::of(&times);
                table.row(vec![
                    name.to_string(),
                    format!("{m}"),
                    kind.name().into(),
                    format!("{}", sig_figs(s.mean, 3)),
                    format!("{}", sig_figs(s.ci_half, 2)),
                ]);
            }
        }
    }
    println!("\nFigure 4 — full-path time vs path length");
    println!("{}", table.render());
    write_csv(cfg, "fig4_path_length", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longer_paths_cost_more_but_sublinearly_for_hessian() {
        let data = simulate(60, 800, 5, 0.4, 2.0, Loss::Gaussian, 3);
        let mut s10 = paper_settings();
        s10.path_length = 10;
        let mut s100 = paper_settings();
        s100.path_length = 100;
        let (f10, _) = fit_timed(&data, ScreeningKind::Hessian, &s10);
        let (f100, _) = fit_timed(&data, ScreeningKind::Hessian, &s100);
        // More steps on the finer grid...
        assert!(f100.lambdas.len() > f10.lambdas.len());
        // ...but pass count grows far slower than 10x (warm starts —
        // the paper's F.1 point about the marginal price of resolution).
        assert!(
            (f100.total_passes() as f64) < 6.0 * f10.total_passes() as f64,
            "passes {} vs {}",
            f100.total_passes(),
            f10.total_passes()
        );
    }
}
