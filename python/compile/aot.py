"""AOT compiler: lower the Layer-2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text via ``HloModuleProto::from_text_file`` → PJRT compile → execute.

HLO **text** — not ``lowered.compile().serialize()`` and not the raw
StableHLO — is the interchange format: jax ≥ 0.5 emits HloModuleProtos
with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are written as ``artifacts/<op>_<shape>.hlo.txt`` plus a
``manifest.tsv`` (op, shape key, dtype, file) that the rust registry
parses. Shapes are fixed at compile time (XLA is shape-specialized);
the registry falls back to the native rust path for other shapes.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shapes the benchmark suite and examples hit on their hot paths.
# (n, p) pairs for the correlation/KKT sweeps:
SWEEP_SHAPES = [
    (200, 2_000),
    (200, 20_000),
    (400, 40_000),
]
# (e, d, n) triples for the Hessian augmentation panels:
PANEL_SHAPES = [
    (64, 16, 200),
    (128, 32, 400),
]

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side can uniformly unwrap with to_tuple*)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(out_dir: str) -> list:
    """Lower every (op, shape) pair; returns manifest rows."""
    os.makedirs(out_dir, exist_ok=True)
    rows = []

    def emit(name: str, key: str, lowered):
        fname = f"{name}_{key}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rows.append((name, key, "f32", fname))
        print(f"  wrote {fname} ({len(text)} chars)")

    for n, p in SWEEP_SHAPES:
        key = f"{n}x{p}"
        # CPU-backend tile targets: collapse the Pallas grid (tp = p,
        # tn = n) so interpret-mode lowering emits one fused gemv — a
        # 280x win over the TPU VMEM tiles on the CPU PJRT plugin
        # (EXPERIMENTS.md §Perf L1). On a real TPU target these would be
        # the (256, 256) VMEM tiles documented in the kernel.
        tiles = dict(tp=p, tn=n)
        emit(
            "xt_r",
            key,
            jax.jit(lambda a, b: model.correlation(a, b, **tiles)).lower(
                spec((p, n)), spec((n, 1))
            ),
        )
        emit(
            "lasso_kkt",
            key,
            jax.jit(lambda a, b, c, d: model.lasso_kkt(a, b, c, d, **tiles)).lower(
                spec((p, n)), spec((n, 1)), spec((n, 1)), spec(())
            ),
        )
        emit(
            "logistic_kkt",
            key,
            jax.jit(lambda a, b, c, d: model.logistic_kkt(a, b, c, d, **tiles)).lower(
                spec((p, n)), spec((n, 1)), spec((n, 1)), spec(())
            ),
        )
    for e, d, n in PANEL_SHAPES:
        key = f"{e}x{d}x{n}"
        emit(
            "gram_block",
            key,
            jax.jit(model.hessian_panel).lower(
                spec((e, n)), spec((n, 1)), spec((d, n))
            ),
        )

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        for r in rows:
            f.write("\t".join(r) + "\n")
    print(f"  wrote manifest.tsv ({len(rows)} artifacts)")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--out", default=None, help="compat: also copy the first sweep module here"
    )
    args = ap.parse_args()
    rows = build_artifacts(args.out_dir)
    if args.out:
        src = os.path.join(args.out_dir, rows[0][3])
        with open(src) as f, open(args.out, "w") as g:
            g.write(f.read())
    print(f"AOT done: {len(rows)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
