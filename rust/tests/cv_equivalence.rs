//! Integration: cross-validation is deterministic and routing-neutral.
//!
//! The CV determinism contract — curves, selections, and the full
//! refit are bit-identical across:
//!   * fold-worker counts (`threads ∈ {1, 4}`),
//!   * zero-copy [`FoldView`] fits vs. materialized `subset_rows` fits
//!     (the retained test oracle),
//!   * engine-routed fold sweeps vs. host-path folds,
//!   * `.hxd`-streamed designs vs. resident matrices.
//!
//! Every assertion is `==` on f64 bits, never tolerance. Shapes shrink
//! under `HX_TEST_SHAPE=small` (miri/sanitizer runs).

mod common;

use common::test_shape;
use hessian_screening::cv::{
    cross_validate, cross_validate_with_engine, fold_assignments, subset_rows, CvSettings,
    FoldView,
};
use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::DenseMatrix;
use hessian_screening::loss::Loss;
use hessian_screening::path::{PathFit, PathFitter, PathSettings};
use hessian_screening::runtime::{EngineSweep, RuntimeEngine, ShardedDesignView};
use hessian_screening::screening::ScreeningKind;
use hessian_screening::storage::{pack_dense, HxdSource};
use std::path::PathBuf;

fn dense_of(data: &hessian_screening::data::Dataset) -> &DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hxd-cv-{}-{tag}.hxd", std::process::id()))
}

fn cv_settings(n_folds: usize, path_length: usize, threads: usize) -> CvSettings {
    let mut s = CvSettings::default();
    s.n_folds = n_folds;
    s.path.path_length = path_length;
    s.threads = threads;
    s
}

fn assert_curves_bits_eq(
    a: &hessian_screening::cv::CvFit,
    b: &hessian_screening::cv::CvFit,
    what: &str,
) {
    assert_eq!(a.lambdas.len(), b.lambdas.len(), "{what}: grid length");
    for k in 0..a.lambdas.len() {
        assert_eq!(
            a.lambdas[k].to_bits(),
            b.lambdas[k].to_bits(),
            "{what}: λ differs at {k}"
        );
        assert_eq!(
            a.cv_mean[k].to_bits(),
            b.cv_mean[k].to_bits(),
            "{what}: cv mean differs at {k}"
        );
        assert_eq!(
            a.cv_se[k].to_bits(),
            b.cv_se[k].to_bits(),
            "{what}: cv se differs at {k}"
        );
    }
    assert_eq!(a.idx_min, b.idx_min, "{what}: idx_min");
    assert_eq!(a.idx_1se, b.idx_1se, "{what}: idx_1se");
    assert_betas_bits_eq(&a.full_fit, &b.full_fit, what);
}

fn assert_betas_bits_eq(a: &PathFit, b: &PathFit, what: &str) {
    assert_eq!(a.betas.len(), b.betas.len(), "{what}: path length");
    for (k, (ba, bb)) in a.betas.iter().zip(&b.betas).enumerate() {
        assert_eq!(ba.len(), bb.len(), "{what}: support size at step {k}");
        for ((ja, va), (jb, vb)) in ba.iter().zip(bb) {
            assert_eq!(ja, jb, "{what}: support differs at step {k}");
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: β[{ja}] differs at step {k}"
            );
        }
    }
}

#[test]
fn cv_curves_bit_identical_across_threads() {
    let (n, p) = test_shape((120, 80), (30, 20));
    let data = SyntheticSpec::new(n, p, 5).rho(0.2).snr(4.0).seed(11).generate();
    let serial = cross_validate(
        &data.design,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &cv_settings(5, 20, 1),
    );
    let threaded = cross_validate(
        &data.design,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &cv_settings(5, 20, 4),
    );
    assert_curves_bits_eq(&serial, &threaded, "threads 1 vs 4");
    assert_eq!(serial.stats.folds.len(), 5);
    assert_eq!(threaded.stats.cv_threads, 4);
}

#[test]
fn foldview_fits_match_materialized_folds() {
    // The tentpole's zero-copy claim, checked against the retained
    // copy oracle: a path fitted through a FoldView is bit-identical
    // to the same path fitted on a materialized row subset.
    let (n, p) = test_shape((90, 40), (24, 12));
    for (loss, kind) in [
        (Loss::Gaussian, ScreeningKind::Hessian),
        (Loss::Logistic, ScreeningKind::Working),
    ] {
        let data = SyntheticSpec::new(n, p, 4)
            .rho(0.25)
            .snr(3.0)
            .loss(loss)
            .seed(13)
            .generate();
        let folds = fold_assignments(n, 3, 5);
        for f in 0..3 {
            let keep: Vec<bool> = folds.iter().map(|&g| g != f).collect();
            let view = FoldView::new(&data.design, &keep);
            let sub = subset_rows(&data.design, &keep);
            let train_y: Vec<f64> = view.rows().iter().map(|&i| data.response[i]).collect();
            let mut ps = PathSettings::default();
            ps.path_length = 15;
            let fit_view = PathFitter::new(loss, kind)
                .with_settings(ps.clone())
                .fit(&view, &train_y);
            let fit_sub = PathFitter::new(loss, kind)
                .with_settings(ps)
                .fit(&sub, &train_y);
            for (la, lb) in fit_view.lambdas.iter().zip(&fit_sub.lambdas) {
                assert_eq!(la.to_bits(), lb.to_bits(), "{loss:?} fold {f}: λ grid");
            }
            assert_betas_bits_eq(&fit_view, &fit_sub, &format!("{loss:?} fold {f}"));
        }
    }
}

#[test]
fn engine_routed_folds_match_host_path() {
    let (n, p) = test_shape((100, 60), (28, 16));
    for (loss, kind) in [
        (Loss::Gaussian, ScreeningKind::Hessian),
        (Loss::Logistic, ScreeningKind::Working),
    ] {
        let data = SyntheticSpec::new(n, p, 4)
            .rho(0.2)
            .snr(4.0)
            .loss(loss)
            .seed(17)
            .generate();
        let settings = cv_settings(4, 15, 2);
        let host = cross_validate(&data.design, &data.response, loss, kind, &settings);
        let engine = RuntimeEngine::native_threaded(2);
        let sweep = EngineSweep::new(&engine, dense_of(&data), loss)
            .expect("register")
            .expect("native backend always binds dense designs");
        let routed = cross_validate_with_engine(
            &data.design,
            &data.response,
            loss,
            kind,
            &settings,
            Some(&sweep),
        );
        assert_curves_bits_eq(&host, &routed, &format!("{loss:?} engine vs host"));
        assert!(routed.stats.routed && !host.stats.routed);
    }
}

#[test]
fn hxd_streamed_cv_matches_resident() {
    // Out-of-core CV: the design registers once from the .hxd source;
    // folds are row-masked views over the sharded registration. The
    // curve must match the resident host-path run bit-for-bit.
    let (n, p) = test_shape((90, 73), (24, 19));
    let data = SyntheticSpec::new(n, p, 4).rho(0.2).snr(4.0).seed(19).generate();
    let settings = cv_settings(4, 12, 2);
    let resident = cross_validate(
        &data.design,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &settings,
    );

    let path = tmp("stream");
    pack_dense(&path, dense_of(&data), 17, Loss::Gaussian, Some(&data.response)).expect("pack");
    let source = HxdSource::open(&path).expect("open");
    let engine = RuntimeEngine::native_sharded(3, 1);
    let sweep = EngineSweep::from_source(&engine, Box::new(source), Loss::Gaussian)
        .expect("register")
        .expect("native backend always binds");
    let view = ShardedDesignView::new(&sweep.design).expect("view");
    let streamed = cross_validate_with_engine(
        &view,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &settings,
        Some(&sweep),
    );
    let _ = std::fs::remove_file(&path);

    assert_curves_bits_eq(&resident, &streamed, "hxd vs resident");
    assert_eq!(streamed.stats.engine_shards, 3);
    assert!(streamed.stats.routed);
}

#[test]
fn fold_seed_changes_the_split() {
    let (n, p) = test_shape((80, 30), (24, 10));
    let data = SyntheticSpec::new(n, p, 3).rho(0.2).snr(4.0).seed(23).generate();
    assert_ne!(fold_assignments(n, 4, 0), fold_assignments(n, 4, 1));
    let mut a = cv_settings(4, 12, 2);
    let mut b = cv_settings(4, 12, 2);
    a.seed = 0;
    b.seed = 1;
    let cv_a = cross_validate(
        &data.design,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &a,
    );
    let cv_b = cross_validate(
        &data.design,
        &data.response,
        Loss::Gaussian,
        ScreeningKind::Hessian,
        &b,
    );
    // Same grid (it comes from the full data), different fold splits →
    // different CV curves. A bitwise-equal curve across seeds would
    // mean the seed isn't actually reaching the assignment shuffle.
    assert_eq!(cv_a.lambdas, cv_b.lambdas);
    assert!(
        cv_a.cv_mean
            .iter()
            .zip(&cv_b.cv_mean)
            .any(|(x, y)| x.to_bits() != y.to_bits()),
        "fold seed did not change the CV curve"
    );
}
