"""Layer-2 graph tests: the fused model functions and their
shape/layout contracts with the rust runtime."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (advisory oracle suite)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (advisory oracle suite)")

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def test_correlation_layout_contract():
    # The rust side passes col-major (n,p) X as row-major (p,n) XT:
    # verify the two views give identical correlations.
    rng = np.random.default_rng(0)
    n, p = 9, 14
    x = rng.standard_normal((n, p)).astype(np.float32)
    # raw col-major buffer of X, reinterpreted as row-major (p, n)
    xt_from_fortran = x.ravel(order="F").reshape(p, n)
    r = rng.standard_normal((n, 1)).astype(np.float32)
    (c,) = model.correlation(jnp.asarray(x.T), jnp.asarray(r))
    (c2,) = model.correlation(jnp.asarray(xt_from_fortran), jnp.asarray(r))
    np.testing.assert_allclose(c, x.T @ r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c, c2, rtol=1e-6)


def test_lasso_kkt_violation_mask_thresholding():
    rng = np.random.default_rng(1)
    xt = rand(rng, 8, 6)
    y = rand(rng, 6, 1)
    eta = jnp.zeros((6, 1), dtype=jnp.float32)
    c, resid, viol = model.lasso_kkt(xt, y, eta, jnp.float32(0.0))
    # λ = 0: every non-zero correlation is a violation.
    np.testing.assert_array_equal(
        np.asarray(viol) > 0, np.abs(np.asarray(c)) > 0
    )
    # huge λ: no violations.
    _, _, none = model.lasso_kkt(xt, y, eta, jnp.float32(1e9))
    assert np.asarray(none).sum() == 0
    np.testing.assert_allclose(resid, y, rtol=1e-6)


def test_logistic_kkt_null_model_correlation():
    # At η = 0, resid = y − 1/2 — the paper's logistic λ_max sweep.
    rng = np.random.default_rng(2)
    xt = rand(rng, 10, 20)
    y = jnp.asarray(rng.integers(0, 2, (20, 1)), dtype=jnp.float32)
    eta = jnp.zeros((20, 1), dtype=jnp.float32)
    c, resid, _ = model.logistic_kkt(xt, y, eta, jnp.float32(0.1))
    np.testing.assert_allclose(resid, np.asarray(y) - 0.5, rtol=1e-6)
    np.testing.assert_allclose(c, xt @ (y - 0.5), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=12),
    d=st.integers(min_value=1, max_value=12),
    n=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hessian_panel_matches_einsum(e, d, n, seed):
    rng = np.random.default_rng(seed)
    xe = rand(rng, e, n)
    xd = rand(rng, d, n)
    w = jnp.asarray(rng.uniform(0.0, 0.25, (n, 1)), dtype=jnp.float32)
    (g,) = model.hessian_panel(xe, w, xd)
    want = np.einsum("en,n,dn->ed", xe, np.asarray(w)[:, 0], xd)
    np.testing.assert_allclose(g, want, rtol=2e-4, atol=2e-5)


def test_kkt_graph_is_single_fusion_candidate():
    # The lowered module should contain exactly one dot op — the
    # elementwise residual/mask work must fuse around it (the L2 §Perf
    # claim in EXPERIMENTS.md).
    lowered = jax.jit(model.lasso_kkt).lower(
        jax.ShapeDtypeStruct((32, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 1), jnp.float32),
        jax.ShapeDtypeStruct((16, 1), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    assert hlo.count("dot(") == 1, hlo


@pytest.mark.parametrize("tp,tn", [(16, 16), (32, 8), (10**6, 10**6)])
def test_tile_targets_do_not_change_results(tp, tn):
    rng = np.random.default_rng(3)
    xt = rand(rng, 40, 24)
    y = rand(rng, 24, 1)
    eta = rand(rng, 24, 1)
    lam = jnp.float32(0.2)
    base = model.lasso_kkt(xt, y, eta, lam)
    tiled = model.lasso_kkt(xt, y, eta, lam, tp=tp, tn=tn)
    for a, b in zip(base, tiled):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
