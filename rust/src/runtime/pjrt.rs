//! PJRT artifact backend (behind the `pjrt` cargo feature).
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py` (HLO
//! text) and executes them on a PJRT CPU client. Ops are compiled at
//! startup (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile`), keyed by (op, shape). Designs are
//! *registered* once — converted to f32 and uploaded as device buffers
//! — so a KKT sweep at solve time moves only the O(n) residual across
//! the FFI.
//!
//! This module type-checks against [`super::xla_stub`]; substituting
//! the real `xla` crate is a one-line import swap (see the stub's
//! module docs).
//!
//! Multi-device fan-out does **not** live here: hand one `PjrtBackend`
//! per device to [`super::ShardedBackend::from_backends`] and the
//! column sharding, pipelined uploads, and mask reduction come for
//! free (the per-shard `supports_sweep` checks then key artifacts on
//! the shard shape, so compile one artifact per shard width).

use super::xla_stub as xla;
use super::{Backend, DesignRepr, KktBatch, RegisteredDesign};
use crate::error::{Context, Result};
use crate::loss::Loss;
use std::collections::HashMap;
use std::path::Path;

/// One compiled artifact.
struct CompiledOp {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT execution backend.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    ops: HashMap<(String, String), CompiledOp>,
}

// NOTE: the stub handles are plain data, so `PjrtBackend` is
// auto-Send/Sync. When the real `xla` crate is swapped in, the
// compiler will demand an explicit (and deliberate) answer to the
// thread-safety question via the `Backend: Send + Sync` bound —
// do NOT paper over it with a blanket `unsafe impl`.

impl PjrtBackend {
    /// Load and compile every artifact listed in `dir`/manifest.tsv.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| crate::err!("pjrt cpu client: {e}"))?;
        let mut ops = HashMap::new();
        for line in text.lines() {
            let parts: Vec<&str> = line.trim().split('\t').collect();
            if parts.len() != 4 {
                continue;
            }
            let (op, key, _dtype, fname) = (parts[0], parts[1], parts[2], parts[3]);
            let path = dir.join(fname);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| crate::err!("non-utf8 path"))?,
            )
            .map_err(|e| crate::err!("parsing {fname}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| crate::err!("compiling {fname}: {e}"))?;
            ops.insert((op.to_string(), key.to_string()), CompiledOp { exe });
        }
        if ops.is_empty() {
            return Err(crate::err!("no artifacts found in {}", dir.display()));
        }
        Ok(Self { client, ops })
    }

    pub fn has(&self, op: &str, key: &str) -> bool {
        self.ops.contains_key(&(op.to_string(), key.to_string()))
    }

    fn shape_key(n: usize, p: usize) -> String {
        format!("{n}x{p}")
    }

    fn buffer(design: &RegisteredDesign) -> Result<&xla::PjRtBuffer> {
        match &design.repr {
            DesignRepr::Pjrt(buf) => Ok(buf),
            _ => Err(crate::err!(
                "design was registered with a different backend"
            )),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn num_ops(&self) -> usize {
        self.ops.len()
    }

    fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        let op = match loss {
            Loss::Gaussian => "lasso_kkt",
            Loss::Logistic => "logistic_kkt",
            Loss::Poisson => return false,
        };
        self.has(op, &Self::shape_key(n, p))
    }

    /// Upload a design (as its raw column-major f64 buffer) to the
    /// device, converting to f32. O(np), once per dataset.
    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        if col_major.len() != n * p {
            return Err(crate::err!(
                "design buffer has {} entries, expected {}x{}",
                col_major.len(),
                n,
                p
            ));
        }
        let f32data: Vec<f32> = col_major.iter().map(|&v| v as f32).collect();
        // Column norms are cached host-side in f64: the look-ahead
        // sphere tests must not depend on f32 rounding.
        let col_norms = (0..p)
            .map(|j| crate::linalg::blas::nrm2(&col_major[j * n..(j + 1) * n]))
            .collect();
        // Column-major (n, p) == row-major (p, n): upload with dims (p, n).
        let buffer = self
            .client
            .buffer_from_host_buffer(&f32data, &[p, n], None)
            .map_err(|e| crate::err!("uploading design: {e}"))?;
        Ok(RegisteredDesign {
            n,
            p,
            col_norms,
            repr: DesignRepr::Pjrt(buffer),
        })
    }

    /// c = Xᵀr through the `xt_r` artifact. Returns None when no
    /// artifact matches the shape.
    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let key = Self::shape_key(design.n, design.p);
        let Some(op) = self.ops.get(&("xt_r".to_string(), key)) else {
            return Ok(None);
        };
        let design_buf = Self::buffer(design)?;
        let rf: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        let rbuf = self
            .client
            .buffer_from_host_buffer(&rf, &[design.n, 1], None)
            .map_err(|e| crate::err!("uploading r: {e}"))?;
        let out = op
            .exe
            .execute_b(&[design_buf, &rbuf])
            .map_err(|e| crate::err!("execute xt_r: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| crate::err!("fetch result: {e}"))?
            .to_tuple1()
            .map_err(|e| crate::err!("untuple: {e}"))?;
        let v: Vec<f32> = lit.to_vec().map_err(|e| crate::err!("to_vec: {e}"))?;
        Ok(Some(v.into_iter().map(|x| x as f64).collect()))
    }

    /// Fused KKT sweep via `lasso_kkt`/`logistic_kkt`. Returns
    /// (c, resid) in f64, or None when no artifact matches.
    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let opname = match loss {
            Loss::Gaussian => "lasso_kkt",
            Loss::Logistic => "logistic_kkt",
            Loss::Poisson => return Ok(None),
        };
        let key = Self::shape_key(design.n, design.p);
        let Some(op) = self.ops.get(&(opname.to_string(), key)) else {
            return Ok(None);
        };
        let design_buf = Self::buffer(design)?;
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let ef: Vec<f32> = eta.iter().map(|&v| v as f32).collect();
        let ybuf = self
            .client
            .buffer_from_host_buffer(&yf, &[design.n, 1], None)
            .map_err(|e| crate::err!("uploading y: {e}"))?;
        let ebuf = self
            .client
            .buffer_from_host_buffer(&ef, &[design.n, 1], None)
            .map_err(|e| crate::err!("uploading eta: {e}"))?;
        let lbuf = self
            .client
            .buffer_from_host_buffer(&[lambda as f32], &[], None)
            .map_err(|e| crate::err!("uploading lambda: {e}"))?;
        let out = op
            .exe
            .execute_b(&[design_buf, &ybuf, &ebuf, &lbuf])
            .map_err(|e| crate::err!("execute {opname}: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| crate::err!("fetch result: {e}"))?;
        let (c_l, r_l, _viol) = lit.to_tuple3().map_err(|e| crate::err!("untuple3: {e}"))?;
        let c: Vec<f32> = c_l.to_vec().map_err(|e| crate::err!("c to_vec: {e}"))?;
        let r: Vec<f32> = r_l.to_vec().map_err(|e| crate::err!("r to_vec: {e}"))?;
        Ok(Some((
            c.into_iter().map(|x| x as f64).collect(),
            r.into_iter().map(|x| x as f64).collect(),
        )))
    }

    /// Batched look-ahead sweep: **stubbed** until a dedicated
    /// `lasso_kkt_batch` AOT artifact exists (the per-λ mask pass is
    /// trivial to fuse device-side, but the op must be lowered by
    /// `python/compile/aot.py` first). Returning `None` makes the
    /// engine fall back to per-λ sequential artifact sweeps, so the
    /// batching surface is wired end-to-end without new artifacts.
    fn kkt_sweep_batch(
        &self,
        _loss: Loss,
        _design: &RegisteredDesign,
        _y: &[f64],
        _eta: &[f64],
        _lambdas: &[f64],
        _l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        Ok(None)
    }

    /// Weighted Gram panel via `gram_block` (Algorithm-1 augmentation).
    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        let key = format!("{e}x{d}x{n}");
        let Some(op) = self.ops.get(&("gram_block".to_string(), key)) else {
            return Ok(None);
        };
        let to32 = |s: &[f64]| s.iter().map(|&v| v as f32).collect::<Vec<f32>>();
        // The artifact always takes a weight vector; unit weights
        // stand in for `None`.
        let w32 = match w {
            Some(w) => to32(w),
            None => vec![1.0f32; n],
        };
        let eb = self
            .client
            .buffer_from_host_buffer(&to32(xe_t), &[e, n], None)
            .map_err(|er| crate::err!("upload xe: {er}"))?;
        let wb = self
            .client
            .buffer_from_host_buffer(&w32, &[n, 1], None)
            .map_err(|er| crate::err!("upload w: {er}"))?;
        let db = self
            .client
            .buffer_from_host_buffer(&to32(xd_t), &[d, n], None)
            .map_err(|er| crate::err!("upload xd: {er}"))?;
        let out = op
            .exe
            .execute_b(&[&eb, &wb, &db])
            .map_err(|er| crate::err!("execute gram_block: {er}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|er| crate::err!("fetch: {er}"))?
            .to_tuple1()
            .map_err(|er| crate::err!("untuple: {er}"))?;
        let v: Vec<f32> = lit.to_vec().map_err(|er| crate::err!("to_vec: {er}"))?;
        Ok(Some(v.into_iter().map(|x| x as f64).collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_key_format() {
        assert_eq!(PjrtBackend::shape_key(200, 2000), "200x2000");
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(PjrtBackend::load_dir(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
