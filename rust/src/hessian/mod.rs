//! Hessian tracking: the paper's Algorithm 1 (sweep-operator updates of
//! H = X_AᵀD(w)X_A and Q = H⁻¹ as the active set changes), the
//! Appendix-C preconditioner, and the eq.-(7) warm start.
//!
//! Complexity matches §3.3.1: a step with leaving set C, entering set D
//! and persisting set E costs
//! O(|D|²n + n|D||E| + |C|³ + |C||E|²) — the Gram panels against X
//! dominate, exactly as the paper argues, and this is what makes the
//! rule affordable relative to an O(|A|³ + |A|²n) rebuild.

#![forbid(unsafe_code)]

use crate::linalg::cholesky::Cholesky;
use crate::linalg::eigen::SymEigen;
use crate::linalg::{DenseMatrix, Design};
use crate::runtime::RuntimeEngine;

/// Materialize `cols` of the design as a row-major (|cols|, n) panel
/// (each row one dense column of X) — the layout
/// [`crate::runtime::Backend::gram_block`] consumes. Writes into a
/// caller-owned buffer so the tracker's panel scratch is reused across
/// Algorithm-1 steps instead of reallocated.
fn gather_columns_into<D: Design + ?Sized>(design: &D, cols: &[usize], out: &mut Vec<f64>) {
    let n = design.nrows();
    out.clear();
    out.resize(cols.len() * n, 0.0);
    for (i, &j) in cols.iter().enumerate() {
        design.col_axpy(j, 1.0, &mut out[i * n..(i + 1) * n]);
    }
}

/// Reusable gather + panel-output buffers for the Algorithm-1 Gram
/// panels (the §3.3.1 hot spot). Grown to the largest panel seen so
/// far, then reused for the rest of the path.
#[derive(Clone, Debug, Default)]
struct PanelScratch {
    /// Gathered entering-column panel X_Dᵀ (row-major d×n).
    xa: Vec<f64>,
    /// Gathered persisting-column panel X_Eᵀ (row-major e×n).
    xb: Vec<f64>,
    /// gram_block output for the d×d (or k×k) panel.
    out_a: Vec<f64>,
    /// gram_block output for the e×d panel.
    out_b: Vec<f64>,
}

/// Tracks H and H⁻¹ for the current active set, in a fixed column order
/// (`active[k]` ↔ row/column k of `h`/`q`).
#[derive(Clone, Debug)]
pub struct HessianTracker<'e> {
    active: Vec<usize>,
    /// H = X_AᵀD(w)X_A (possibly already including the preconditioner α
    /// on the diagonal — see `precondition`).
    h: DenseMatrix,
    /// Q = H⁻¹ (preconditioned when applicable).
    q: DenseMatrix,
    /// Appendix-C ridge α = n·10⁻⁴.
    alpha: f64,
    /// Optional compute engine: when set, the Algorithm-1 Gram panels
    /// (augmentation blocks and rebuilds — the §3.3.1 cost drivers)
    /// are formed by blocked [`crate::runtime::Backend::gram_block`]
    /// calls instead of per-entry `gram_weighted` loops. Falls back to
    /// the scalar loops whenever the backend has no panel kernel.
    engine: Option<&'e RuntimeEngine>,
    /// Reused gather/panel buffers (see [`PanelScratch`]).
    scratch: PanelScratch,
    /// Wall-clock seconds spent forming H (panels + sweep algebra)
    /// since the last [`Self::take_panel_seconds`] call.
    panel_seconds: f64,
    /// Count of sweep updates / rebuilds, for the experiment breakdowns.
    pub n_sweep_updates: usize,
    pub n_rebuilds: usize,
    /// Panels served by the engine (vs. scalar fallback loops).
    pub n_engine_panels: usize,
}

impl<'e> HessianTracker<'e> {
    /// `alpha` is the preconditioning constant (paper: n·10⁻⁴).
    pub fn new(alpha: f64) -> Self {
        Self {
            active: Vec::new(),
            h: DenseMatrix::zeros(0, 0),
            q: DenseMatrix::zeros(0, 0),
            alpha,
            engine: None,
            scratch: PanelScratch::default(),
            panel_seconds: 0.0,
            n_sweep_updates: 0,
            n_rebuilds: 0,
            n_engine_panels: 0,
        }
    }

    /// Drain the Hessian-maintenance timer: seconds spent inside
    /// [`Self::rebuild`]/[`Self::update`] since the previous call.
    /// The path driver reads this once per step to fill the profile's
    /// `t_panel` column.
    pub fn take_panel_seconds(&mut self) -> f64 {
        std::mem::replace(&mut self.panel_seconds, 0.0)
    }

    /// Route Gram-panel formation through a compute backend.
    pub fn with_engine(mut self, engine: &'e RuntimeEngine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Symmetric blocked panel X_Aᵀ D(w) X_A through the engine into
    /// `self.scratch.out_a`; returns `false` when no engine/kernel is
    /// available (callers keep their scalar loop). Gathers the columns
    /// once into the reused `scratch.xa` buffer.
    fn engine_sym_panel<D: Design + ?Sized>(
        &mut self,
        design: &D,
        cols: &[usize],
        w: Option<&[f64]>,
    ) -> bool {
        let engine = match self.engine {
            Some(e) => e,
            None => return false,
        };
        let k = cols.len();
        gather_columns_into(design, cols, &mut self.scratch.xa);
        matches!(
            engine.gram_block_into(
                &self.scratch.xa,
                w,
                &self.scratch.xa,
                k,
                k,
                design.nrows(),
                &mut self.scratch.out_a,
            ),
            Ok(true)
        )
    }

    pub fn active(&self) -> &[usize] {
        &self.active
    }

    pub fn dim(&self) -> usize {
        self.active.len()
    }

    pub fn h(&self) -> &DenseMatrix {
        &self.h
    }

    pub fn q(&self) -> &DenseMatrix {
        &self.q
    }

    /// v = Q·s for a vector ordered like `active`.
    pub fn q_times(&self, s: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.q_times_into(s, &mut out);
        out
    }

    /// [`Self::q_times`] into a caller-owned buffer (reused per step by
    /// the path driver's workspace).
    pub fn q_times_into(&self, s: &[f64], out: &mut Vec<f64>) {
        assert_eq!(s.len(), self.dim());
        out.clear();
        out.resize(self.dim(), 0.0);
        self.q.gemv(s, out);
    }

    /// Rebuild H and Q from scratch for `new_active` (weights `w`,
    /// `None` = unweighted). O(|A|²n + |A|³). Used at the first step,
    /// for GLM "full updates" (§3.3.3) and by the no-sweep ablation.
    pub fn rebuild<D: Design + ?Sized>(
        &mut self,
        design: &D,
        new_active: &[usize],
        w: Option<&[f64]>,
    ) {
        let t0 = std::time::Instant::now();
        let k = new_active.len();
        let mut h = DenseMatrix::zeros(k, k);
        // Blocked panel through the engine when available (one
        // gram_block call instead of k(k+1)/2 scalar gram_weighted
        // calls); per-entry values are identical, so the scalar loop
        // below stays the reference fallback.
        let use_panel = k > 0 && self.engine_sym_panel(design, new_active, w);
        if use_panel {
            self.n_engine_panels += 1;
            // Mirror the lower triangle: dot_w(x, y, w) and
            // dot_w(y, x, w) can differ in the last bit (float
            // multiplication is not associative), and H must stay
            // exactly symmetric — matching the scalar loop below.
            let panel = &self.scratch.out_a;
            for a in 0..k {
                for b in 0..=a {
                    let v = panel[a * k + b];
                    *h.at_mut(a, b) = v;
                    *h.at_mut(b, a) = v;
                }
            }
        } else {
            for a in 0..k {
                for b in 0..=a {
                    let v = design.gram_weighted(new_active[a], new_active[b], w);
                    *h.at_mut(a, b) = v;
                    *h.at_mut(b, a) = v;
                }
            }
        }
        self.active.clear();
        self.active.extend_from_slice(new_active);
        self.install(h);
        self.n_rebuilds += 1;
        self.panel_seconds += t0.elapsed().as_secs_f64();
        #[cfg(feature = "paranoid")]
        crate::invariants::assert_gram_symmetric(&self.h, "HessianTracker::rebuild");
    }

    /// Algorithm 1: update from the current active set to `new_active`
    /// with the *reduction* step (Schur complement on the leaving block)
    /// followed by the *augmentation* step (block-inverse on the
    /// entering block). Weights must be the same as those used to build
    /// the current H (sweep updates are only valid when D(w) is fixed —
    /// §3.3.3; for GLMs that is the upper-bound regime).
    pub fn update<D: Design + ?Sized>(
        &mut self,
        design: &D,
        new_active: &[usize],
        w: Option<&[f64]>,
    ) {
        let t0 = std::time::Instant::now();
        let new_set: std::collections::HashSet<usize> = new_active.iter().copied().collect();
        // Positions (in the current ordering) that stay / leave.
        let keep_pos: Vec<usize> = (0..self.active.len())
            .filter(|&k| new_set.contains(&self.active[k]))
            .collect();
        let drop_pos: Vec<usize> = (0..self.active.len())
            .filter(|&k| !new_set.contains(&self.active[k]))
            .collect();

        // --- Reduction: Q_EE − Q_EC Q_CC⁻¹ Q_CE ; H → H_EE. ---
        if !drop_pos.is_empty() {
            let e = keep_pos.len();
            let c = drop_pos.len();
            let mut q_ee = DenseMatrix::zeros(e, e);
            let mut q_ec = DenseMatrix::zeros(e, c);
            let mut q_cc = DenseMatrix::zeros(c, c);
            let mut h_ee = DenseMatrix::zeros(e, e);
            for (a, &pa) in keep_pos.iter().enumerate() {
                for (b, &pb) in keep_pos.iter().enumerate() {
                    *q_ee.at_mut(a, b) = self.q.at(pa, pb);
                    *h_ee.at_mut(a, b) = self.h.at(pa, pb);
                }
                for (b, &pb) in drop_pos.iter().enumerate() {
                    *q_ec.at_mut(a, b) = self.q.at(pa, pb);
                }
            }
            for (a, &pa) in drop_pos.iter().enumerate() {
                for (b, &pb) in drop_pos.iter().enumerate() {
                    *q_cc.at_mut(a, b) = self.q.at(pa, pb);
                }
            }
            // Q_CC is a principal sub-matrix of an SPD matrix ⇒ SPD.
            let q_new = match Cholesky::factor(&q_cc) {
                Ok(ch) => {
                    // M = Q_CC⁻¹ Q_CE  (solve per column of Q_ECᵀ)
                    let mut m = DenseMatrix::zeros(c, e);
                    let mut col = vec![0.0; c];
                    for j in 0..e {
                        for i in 0..c {
                            col[i] = q_ec.at(j, i);
                        }
                        ch.solve_in_place(&mut col);
                        for i in 0..c {
                            *m.at_mut(i, j) = col[i];
                        }
                    }
                    // Q_EE − Q_EC·M
                    let correction = q_ec.gemm(&m);
                    let mut q_new = q_ee;
                    for j in 0..e {
                        for i in 0..e {
                            *q_new.at_mut(i, j) -= correction.at(i, j);
                        }
                    }
                    q_new
                }
                Err(_) => {
                    // Degenerate Q_CC (can happen after aggressive
                    // preconditioning): fall back to inverting H_EE.
                    invert_spd_preconditioned(&h_ee, self.alpha)
                }
            };
            self.active.retain(|j| new_set.contains(j));
            self.h = h_ee;
            self.q = q_new;
        }

        // --- Augmentation: entering block D. ---
        let have: std::collections::HashSet<usize> = self.active.iter().copied().collect();
        let entering: Vec<usize> = new_active
            .iter()
            .copied()
            .filter(|j| !have.contains(j))
            .collect();
        if !entering.is_empty() {
            let e = self.active.len();
            let d = entering.len();
            // Gram panels against X (the O(n|D||E|) + O(n|D|²) cost) —
            // the §3.3.1 hot spot. Routed through the engine as two
            // blocked gram_block panels when available; otherwise the
            // per-entry scalar loops below.
            let mut g_ed = DenseMatrix::zeros(e, d);
            let mut g_dd = DenseMatrix::zeros(d, d);
            let n = design.nrows();
            // Each column set is gathered exactly once into the reused
            // scratch buffers; the counter is bumped only when both
            // panels are actually consumed.
            let panels_ok = match self.engine {
                Some(engine) => {
                    gather_columns_into(design, &entering, &mut self.scratch.xa);
                    matches!(
                        engine.gram_block_into(
                            &self.scratch.xa,
                            w,
                            &self.scratch.xa,
                            d,
                            d,
                            n,
                            &mut self.scratch.out_a,
                        ),
                        Ok(true)
                    ) && {
                        gather_columns_into(design, &self.active, &mut self.scratch.xb);
                        matches!(
                            engine.gram_block_into(
                                &self.scratch.xb,
                                w,
                                &self.scratch.xa,
                                e,
                                d,
                                n,
                                &mut self.scratch.out_b,
                            ),
                            Ok(true)
                        )
                    }
                }
                None => false,
            };
            if panels_ok {
                self.n_engine_panels += 2;
                // Both panels row-major: out_a is (d, d), out_b is
                // (e, d). G_DD is mirrored from one triangle for exact
                // symmetry (see the rebuild comment).
                let dd = &self.scratch.out_a;
                let ed = &self.scratch.out_b;
                for b in 0..d {
                    for a in 0..e {
                        *g_ed.at_mut(a, b) = ed[a * d + b];
                    }
                    for a in 0..=b {
                        let v = dd[a * d + b];
                        *g_dd.at_mut(a, b) = v;
                        *g_dd.at_mut(b, a) = v;
                    }
                }
            } else {
                for (b, &jd) in entering.iter().enumerate() {
                    for (a, &je) in self.active.iter().enumerate() {
                        *g_ed.at_mut(a, b) = design.gram_weighted(je, jd, w);
                    }
                    for (a, &ja) in entering.iter().enumerate().take(b + 1) {
                        let v = design.gram_weighted(ja, jd, w);
                        *g_dd.at_mut(a, b) = v;
                        *g_dd.at_mut(b, a) = v;
                    }
                }
            }
            // T = Q·G_ED ; S = G_DD − G_EDᵀ·T (Schur complement).
            let t = self.q.gemm(&g_ed);
            let mut s = g_dd.clone();
            let gt = g_ed.t_gemm(&t); // (d×d) = G_EDᵀ T
            for j in 0..d {
                for i in 0..d {
                    *s.at_mut(i, j) -= gt.at(i, j);
                }
            }
            // S⁻¹ with the Appendix-C preconditioner when needed.
            let s_inv = invert_spd_preconditioned(&s, self.alpha);

            // Assemble Q_new = [[Q + T S⁻¹ Tᵀ, −T S⁻¹], [−S⁻¹ Tᵀ, S⁻¹]].
            let ts = t.gemm(&s_inv); // e×d
            let mut q_new = DenseMatrix::zeros(e + d, e + d);
            let tst = ts.gemm(&t.transpose()); // e×e
            for j in 0..e {
                for i in 0..e {
                    *q_new.at_mut(i, j) = self.q.at(i, j) + tst.at(i, j);
                }
            }
            for j in 0..d {
                for i in 0..e {
                    *q_new.at_mut(i, e + j) = -ts.at(i, j);
                    *q_new.at_mut(e + j, i) = -ts.at(i, j);
                }
                for i in 0..d {
                    *q_new.at_mut(e + i, e + j) = s_inv.at(i, j);
                }
            }
            // H_new = [[H, G_ED], [G_EDᵀ, G_DD]].
            let mut h_new = DenseMatrix::zeros(e + d, e + d);
            for j in 0..e {
                for i in 0..e {
                    *h_new.at_mut(i, j) = self.h.at(i, j);
                }
            }
            for j in 0..d {
                for i in 0..e {
                    *h_new.at_mut(i, e + j) = g_ed.at(i, j);
                    *h_new.at_mut(e + j, i) = g_ed.at(i, j);
                }
                for i in 0..d {
                    *h_new.at_mut(e + i, e + j) = g_dd.at(i, j);
                }
            }
            self.active.extend_from_slice(&entering);
            self.h = h_new;
            self.q = q_new;
        }
        self.n_sweep_updates += 1;
        self.panel_seconds += t0.elapsed().as_secs_f64();
        #[cfg(feature = "paranoid")]
        crate::invariants::assert_gram_symmetric(&self.h, "HessianTracker::update");
    }

    /// Install a freshly computed H, inverting it with preconditioning.
    fn install(&mut self, h: DenseMatrix) {
        self.q = invert_spd_preconditioned(&h, self.alpha);
        self.h = h;
    }

    /// Warm start of eq. (7): given signs s of β̂_A and the λ decrement,
    /// returns Δβ (ordered like `active`) = (λ_k − λ_{k+1}) · Q · s.
    pub fn warm_start_delta(&self, signs: &[f64], lambda_drop: f64) -> Vec<f64> {
        let mut d = self.q_times(signs);
        for v in d.iter_mut() {
            *v *= lambda_drop;
        }
        d
    }

    /// Max |H·Q − I| — a health metric used in tests and debug assertions.
    pub fn inverse_error(&self) -> f64 {
        let k = self.dim();
        if k == 0 {
            return 0.0;
        }
        let prod = self.h.gemm(&self.q);
        prod.max_abs_diff(&DenseMatrix::identity(k))
    }
}

/// Invert an SPD (or nearly-SPD) matrix with the Appendix-C policy:
/// try Cholesky; on failure (or a dangerously small pivot) fall back to
/// the spectral route Q(Λ + αI)⁻¹Qᵀ, adding α only when
/// min eig < α, exactly as the paper prescribes.
pub fn invert_spd_preconditioned(a: &DenseMatrix, alpha: f64) -> DenseMatrix {
    let k = a.nrows();
    if k == 0 {
        return DenseMatrix::zeros(0, 0);
    }
    // Fast path: well-conditioned Cholesky.
    if let Ok(ch) = Cholesky::factor(a) {
        // Check the smallest pivot as a proxy for min eig.
        let min_pivot = (0..k).map(|i| ch.l().at(i, i)).fold(f64::INFINITY, f64::min);
        if min_pivot * min_pivot > alpha {
            return ch.inverse();
        }
    }
    // Appendix C: spectral decomposition; shift if min eig < α.
    let eig = SymEigen::factor(a);
    if eig.min_eigenvalue() < alpha {
        eig.apply_spectral(|l| 1.0 / (l + alpha))
    } else {
        eig.apply_spectral(|l| 1.0 / l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DesignMatrix;
    use crate::testkit::{forall, Config, Gen};

    fn gram_direct(design: &DesignMatrix, active: &[usize], w: Option<&[f64]>) -> DenseMatrix {
        let k = active.len();
        let mut h = DenseMatrix::zeros(k, k);
        for a in 0..k {
            for b in 0..k {
                *h.at_mut(a, b) = design.gram_weighted(active[a], active[b], w);
            }
        }
        h
    }

    #[test]
    fn rebuild_matches_direct_gram_and_inverse() {
        let mut g = Gen::new(1);
        let x = DesignMatrix::Dense(g.gaussian_matrix(30, 10));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[1, 4, 7], None);
        let h = gram_direct(&x, &[1, 4, 7], None);
        assert!(t.h().max_abs_diff(&h) < 1e-12);
        assert!(t.inverse_error() < 1e-8, "inv err {}", t.inverse_error());
    }

    #[test]
    fn augmentation_only_matches_rebuild() {
        let mut g = Gen::new(2);
        let x = DesignMatrix::Dense(g.gaussian_matrix(40, 12));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[0, 3], None);
        t.update(&x, &[0, 3, 5, 9], None);
        let mut fresh = HessianTracker::new(1e-8);
        fresh.rebuild(&x, &[0, 3, 5, 9], None);
        assert_eq!(t.active(), &[0, 3, 5, 9]);
        assert!(t.h().max_abs_diff(fresh.h()) < 1e-10);
        assert!(t.q().max_abs_diff(fresh.q()) < 1e-8);
    }

    #[test]
    fn reduction_only_matches_rebuild() {
        let mut g = Gen::new(3);
        let x = DesignMatrix::Dense(g.gaussian_matrix(40, 12));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[0, 2, 5, 9, 11], None);
        t.update(&x, &[0, 5, 11], None);
        let mut fresh = HessianTracker::new(1e-8);
        fresh.rebuild(&x, &[0, 5, 11], None);
        assert_eq!(t.active(), &[0, 5, 11]);
        assert!(t.h().max_abs_diff(fresh.h()) < 1e-9);
        assert!(t.q().max_abs_diff(fresh.q()) < 1e-7);
    }

    #[test]
    fn simultaneous_enter_and_leave() {
        let mut g = Gen::new(4);
        let x = DesignMatrix::Dense(g.gaussian_matrix(50, 15));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[1, 2, 3, 8], None);
        t.update(&x, &[2, 8, 10, 14, 4], None);
        let expected: Vec<usize> = vec![2, 8, 10, 14, 4];
        let mut sorted_active = t.active().to_vec();
        let mut sorted_expected = expected.clone();
        sorted_active.sort_unstable();
        sorted_expected.sort_unstable();
        assert_eq!(sorted_active, sorted_expected);
        let h = gram_direct(&x, t.active(), None);
        assert!(t.h().max_abs_diff(&h) < 1e-9);
        assert!(t.inverse_error() < 1e-7);
    }

    #[test]
    fn weighted_updates_match() {
        let mut g = Gen::new(5);
        let x = DesignMatrix::Dense(g.gaussian_matrix(30, 8));
        let w: Vec<f64> = (0..30).map(|i| 0.1 + 0.2 * ((i % 4) as f64)).collect();
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[0, 2], Some(&w));
        t.update(&x, &[0, 2, 6], Some(&w));
        let h = gram_direct(&x, t.active(), Some(&w));
        assert!(t.h().max_abs_diff(&h) < 1e-10);
        assert!(t.inverse_error() < 1e-8);
    }

    #[test]
    fn empty_transitions() {
        let mut g = Gen::new(6);
        let x = DesignMatrix::Dense(g.gaussian_matrix(20, 6));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[], None);
        assert_eq!(t.dim(), 0);
        t.update(&x, &[3], None);
        assert_eq!(t.active(), &[3]);
        t.update(&x, &[], None);
        assert_eq!(t.dim(), 0);
        assert_eq!(t.inverse_error(), 0.0);
    }

    #[test]
    fn duplicate_columns_are_preconditioned_not_fatal() {
        // Two identical columns ⇒ singular Gram; Appendix-C ridge keeps
        // the tracker finite.
        let mut g = Gen::new(7);
        let mut m = g.gaussian_matrix(20, 4);
        let c0: Vec<f64> = m.col(0).to_vec();
        m.col_mut(1).copy_from_slice(&c0);
        let x = DesignMatrix::Dense(m);
        let mut t = HessianTracker::new(20.0 * 1e-4);
        t.rebuild(&x, &[0, 1], None);
        assert!(t.q().data().iter().all(|v| v.is_finite()));
        let d = t.warm_start_delta(&[1.0, 1.0], 0.5);
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn warm_start_delta_formula() {
        let mut g = Gen::new(8);
        let x = DesignMatrix::Dense(g.gaussian_matrix(25, 5));
        let mut t = HessianTracker::new(1e-10);
        t.rebuild(&x, &[0, 1, 2], None);
        let signs = vec![1.0, -1.0, 1.0];
        let d = t.warm_start_delta(&signs, 0.3);
        // compare against direct solve H x = s scaled by 0.3
        let h = gram_direct(&x, &[0, 1, 2], None);
        let sol = Cholesky::factor(&h).unwrap().solve(&signs);
        for i in 0..3 {
            assert!((d[i] - 0.3 * sol[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn property_random_transition_chains() {
        forall(Config { cases: 12, seed: 99 }, |g| {
            let n = g.usize_in(15, 40);
            let p = g.usize_in(6, 14);
            let x = DesignMatrix::Dense(g.gaussian_matrix(n, p));
            let mut t = HessianTracker::new(1e-8);
            let mut current: Vec<usize> = Vec::new();
            for _step in 0..5 {
                let k = g.usize_in(0, p.min(n) - 1);
                let next = g.rng.sample_indices(p, k);
                if current.is_empty() {
                    t.rebuild(&x, &next, None);
                } else {
                    t.update(&x, &next, None);
                }
                current = next;
                let h = gram_direct(&x, t.active(), None);
                if t.h().max_abs_diff(&h) > 1e-7 {
                    return Err(format!("H drift {}", t.h().max_abs_diff(&h)));
                }
                if t.inverse_error() > 1e-5 {
                    return Err(format!("Q drift {}", t.inverse_error()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn engine_routed_panels_match_scalar_bitwise() {
        // Routing Algorithm-1 panels through Backend::gram_block must
        // not change a single bit: the blocked kernel runs the same
        // per-entry dot products as the scalar gram_weighted loop.
        let mut g = Gen::new(12);
        let x = DesignMatrix::Dense(g.gaussian_matrix(40, 14));
        let engine = crate::runtime::RuntimeEngine::native_threaded(2);
        let mut scalar = HessianTracker::new(1e-8);
        let mut routed = HessianTracker::new(1e-8).with_engine(&engine);
        scalar.rebuild(&x, &[0, 3, 7], None);
        routed.rebuild(&x, &[0, 3, 7], None);
        assert_eq!(routed.n_engine_panels, 1, "rebuild must use the engine");
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
        assert_eq!(scalar.q().max_abs_diff(routed.q()), 0.0);
        scalar.update(&x, &[0, 7, 9, 12], None);
        routed.update(&x, &[0, 7, 9, 12], None);
        assert_eq!(routed.n_engine_panels, 3, "augmentation must use the engine");
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
        assert_eq!(scalar.q().max_abs_diff(routed.q()), 0.0);
        // Weighted (GLM full-Hessian) panels too.
        let w: Vec<f64> = (0..40).map(|i| 0.1 + 0.15 * ((i % 5) as f64)).collect();
        scalar.rebuild(&x, &[1, 2, 5], Some(&w));
        routed.rebuild(&x, &[1, 2, 5], Some(&w));
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
    }

    #[test]
    fn sharded_engine_panels_match_scalar_bitwise() {
        // Same contract as the threaded-engine test, through the
        // sharded backend: fanning panel rows across shard engines
        // must not change a single bit (ragged row split: 3 engines,
        // panels with e ∈ {3, 4}).
        let mut g = Gen::new(17);
        let x = DesignMatrix::Dense(g.gaussian_matrix(40, 14));
        let engine = crate::runtime::RuntimeEngine::native_sharded(3, 1);
        let mut scalar = HessianTracker::new(1e-8);
        let mut routed = HessianTracker::new(1e-8).with_engine(&engine);
        scalar.rebuild(&x, &[0, 3, 7], None);
        routed.rebuild(&x, &[0, 3, 7], None);
        assert_eq!(routed.n_engine_panels, 1, "rebuild must use the engine");
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
        assert_eq!(scalar.q().max_abs_diff(routed.q()), 0.0);
        scalar.update(&x, &[0, 7, 9, 12], None);
        routed.update(&x, &[0, 7, 9, 12], None);
        assert_eq!(routed.n_engine_panels, 3, "augmentation must use the engine");
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
        assert_eq!(scalar.q().max_abs_diff(routed.q()), 0.0);
        let w: Vec<f64> = (0..40).map(|i| 0.1 + 0.15 * ((i % 5) as f64)).collect();
        scalar.rebuild(&x, &[1, 2, 5], Some(&w));
        routed.rebuild(&x, &[1, 2, 5], Some(&w));
        assert_eq!(scalar.h().max_abs_diff(routed.h()), 0.0);
    }

    #[test]
    fn sweep_counters_track_calls() {
        let mut g = Gen::new(11);
        let x = DesignMatrix::Dense(g.gaussian_matrix(20, 6));
        let mut t = HessianTracker::new(1e-8);
        t.rebuild(&x, &[0], None);
        t.update(&x, &[0, 1], None);
        t.update(&x, &[1], None);
        assert_eq!(t.n_rebuilds, 1);
        assert_eq!(t.n_sweep_updates, 2);
    }
}
