//! Integration: column-sharded execution is bit-identical to the
//! unsharded backend — through the raw [`Backend`] ops, the Gram
//! panels, and whole fitted paths.
//!
//! Sharding, like threading, must be a pure wall-clock knob: every
//! output entry is produced by the same per-column scalar kernel the
//! serial backend runs, the per-shard results are concatenated in
//! shard order, and the look-ahead keep-masks are rebuilt from the
//! *global* correlation vector. These tests assert `==` on f64
//! outputs, never tolerance.
//!
//! The CI matrix drives the same tests across configurations via env
//! knobs: `HX_TEST_THREADS` (threads per shard / reference engine
//! threads, default 1), `HX_TEST_SHARDS` (an extra shard count to
//! include, on top of the always-tested {1, 2, 4}), and
//! `HX_TEST_SHAPE=small` (shrunk shapes for miri/sanitizer runs).

mod common;

use common::test_shape;
use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::loss::Loss;
use hessian_screening::path::{PathFitter, PathSettings};
use hessian_screening::runtime::{EngineSweep, KktBatch, RuntimeEngine};
use hessian_screening::screening::ScreeningKind;

fn dense_of(data: &hessian_screening::data::Dataset) -> &hessian_screening::linalg::DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok()?.trim().parse().ok()
}

/// Threads per shard (and reference-engine threads) — CI matrix knob.
fn test_threads() -> usize {
    env_usize("HX_TEST_THREADS").unwrap_or(1).max(1)
}

/// Shard counts under test: the 1-shard degenerate case, 2, 4, plus
/// whatever the CI matrix adds via HX_TEST_SHARDS.
fn shard_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2, 4];
    if let Some(k) = env_usize("HX_TEST_SHARDS") {
        if k >= 1 && !counts.contains(&k) {
            counts.push(k);
        }
    }
    counts
}

#[test]
fn sharded_correlation_bit_identical_ragged() {
    // p is not divisible by 2 or 4 at either size: the final shard is
    // ragged.
    let (n, p) = test_shape((60, 1_003), (16, 103));
    let data = SyntheticSpec::new(n, p, 8).rho(0.3).seed(41).generate();
    let dense = dense_of(&data);
    let reference = RuntimeEngine::native_threaded(test_threads());
    let reg_ref = reference.register_design(dense.data(), n, p).unwrap();
    let c_ref = reference
        .correlation(&reg_ref, &data.response)
        .unwrap()
        .expect("native kernel");
    for shards in shard_counts() {
        let engine = RuntimeEngine::native_sharded(shards, test_threads());
        assert_eq!(engine.backend_name(), "sharded");
        assert_eq!(engine.shards(), shards);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let c = engine
            .correlation(&reg, &data.response)
            .unwrap()
            .expect("sharded kernel");
        assert_eq!(c, c_ref, "{shards} shards: correlation must not change bits");
    }
}

#[test]
fn sharded_kkt_sweeps_bit_identical_gaussian_and_logistic() {
    let (n, p) = test_shape((50, 407), (14, 53)); // ragged for 2 and 4 shards
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 6)
            .rho(0.25)
            .loss(loss)
            .seed(43)
            .generate();
        let dense = dense_of(&data);
        let eta = vec![0.05; n];
        let lambdas = [0.8, 0.55, 0.3];
        let reference = RuntimeEngine::native_threaded(test_threads());
        let reg_ref = reference.register_design(dense.data(), n, p).unwrap();
        let (c_ref, r_ref) = reference
            .kkt_sweep(loss, &reg_ref, &data.response, &eta, 0.5)
            .unwrap()
            .expect("native kernel");
        let batch_ref = reference
            .kkt_sweep_batch(loss, &reg_ref, &data.response, &eta, &lambdas, 1.2)
            .unwrap()
            .expect("native batch kernel");
        for shards in shard_counts() {
            let engine = RuntimeEngine::native_sharded(shards, test_threads());
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            let (c, r) = engine
                .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
                .unwrap()
                .expect("sharded kernel");
            assert_eq!(c, c_ref, "{loss:?} {shards} shards: kkt_sweep c");
            assert_eq!(r, r_ref, "{loss:?} {shards} shards: kkt_sweep resid");
            // The batched masks must come from the *global* sup-norm —
            // a shard-local reduction would produce different (unsound)
            // dual scales. Bit-equality proves the reduction is right.
            let batch = engine
                .kkt_sweep_batch(loss, &reg, &data.response, &eta, &lambdas, 1.2)
                .unwrap()
                .expect("sharded batch kernel");
            assert_eq!(batch.c, batch_ref.c, "{loss:?} {shards} shards: batch c");
            assert_eq!(
                batch.resid, batch_ref.resid,
                "{loss:?} {shards} shards: batch resid"
            );
            assert_eq!(
                batch.keep, batch_ref.keep,
                "{loss:?} {shards} shards: keep-masks"
            );
        }
    }
}

#[test]
fn sharded_gram_block_bit_identical_ragged_rows() {
    // e = 7 rows fanned over up to 4 engines: ragged row split; also
    // exercise e = 0 (empty panel) and unweighted vs weighted.
    let (e, d, n) = (7, 5, 40);
    let data = SyntheticSpec::new(n, e + d, 4).seed(47).generate();
    let dense = dense_of(&data);
    let mut xe_t = Vec::with_capacity(e * n);
    for j in 0..e {
        xe_t.extend_from_slice(dense.col(j));
    }
    let mut xd_t = Vec::with_capacity(d * n);
    for j in e..e + d {
        xd_t.extend_from_slice(dense.col(j));
    }
    let w: Vec<f64> = (0..n).map(|i| 0.2 + 0.1 * ((i % 4) as f64)).collect();
    let reference = RuntimeEngine::native_threaded(test_threads());
    for shards in shard_counts() {
        let engine = RuntimeEngine::native_sharded(shards, test_threads());
        for weights in [None, Some(&w[..])] {
            let want = reference
                .gram_block(&xe_t, weights, &xd_t, e, d, n)
                .unwrap()
                .unwrap();
            let got = engine
                .gram_block(&xe_t, weights, &xd_t, e, d, n)
                .unwrap()
                .unwrap();
            assert_eq!(got, want, "{shards} shards, weighted={}", weights.is_some());
        }
        assert_eq!(
            engine.gram_block(&[], None, &xd_t, 0, d, n).unwrap().unwrap(),
            Vec::<f64>::new(),
            "{shards} shards: empty panel"
        );
    }
}

/// The acceptance bar: `--shards k` path fits are bit-identical to the
/// unsharded serial fits for k ∈ {1, 2, 4}, Gaussian and logistic.
#[test]
fn sharded_path_fits_bit_identical_to_unsharded() {
    let (n, p) = test_shape((100, 902), (24, 61)); // ragged for 4 shards
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 8)
            .rho(0.35)
            .loss(loss)
            .seed(53)
            .generate();
        let dense = dense_of(&data);
        let mut settings = PathSettings::default();
        settings.path_length = 30;
        let fitter = PathFitter::new(loss, ScreeningKind::Hessian).with_settings(settings);
        let reference = RuntimeEngine::native_threaded(test_threads());
        let sweep_ref = EngineSweep::new(&reference, dense, loss).unwrap().unwrap();
        let a = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep_ref));
        for shards in shard_counts() {
            let engine = RuntimeEngine::native_sharded(shards, test_threads());
            let sweep = EngineSweep::new(&engine, dense, loss).unwrap().unwrap();
            let b = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
            assert_eq!(a.lambdas, b.lambdas, "{loss:?} {shards} shards: λ grid");
            assert_eq!(a.betas, b.betas, "{loss:?} {shards} shards: coefficients");
            assert_eq!(
                a.dev_ratios, b.dev_ratios,
                "{loss:?} {shards} shards: deviance ratios"
            );
            assert_eq!(a.converged, b.converged, "{loss:?} {shards} shards");
            // The per-step instrumentation records the shard count.
            assert!(
                b.steps.iter().all(|s| s.shards == shards),
                "{loss:?} {shards} shards: StepStats.shards not recorded"
            );
            assert!(
                a.steps.iter().all(|s| s.shards == 1),
                "{loss:?}: unsharded engine must record shards = 1"
            );
        }
    }
}

/// The allocation-reusing `_into` twins must return bit-identical
/// buffers to the allocating entry points — through the native
/// backend's true in-place kernels AND the sharded backend's default
/// shims — with caller buffers deliberately dirty and wrong-sized, and
/// reused across calls (the workspace-arena steady state).
#[test]
fn into_twins_bit_identical_native_and_sharded() {
    let (n, p) = test_shape((48, 311), (14, 53)); // ragged for 3 shards
    let loss = Loss::Logistic;
    let data = SyntheticSpec::new(n, p, 6)
        .rho(0.3)
        .loss(loss)
        .seed(61)
        .generate();
    let dense = dense_of(&data);
    let eta = vec![0.05; n];
    let lambdas = [0.8, 0.55, 0.3];
    let engines = [
        RuntimeEngine::native_threaded(test_threads()),
        RuntimeEngine::native_sharded(3, test_threads()),
    ];
    for engine in &engines {
        let name = engine.backend_name();
        let reg = engine.register_design(dense.data(), n, p).unwrap();

        let want_c = engine
            .correlation(&reg, &data.response)
            .unwrap()
            .expect("kernel");
        let mut c = vec![f64::NAN; 7]; // dirty + wrong-sized on purpose
        assert!(engine.correlation_into(&reg, &data.response, &mut c).unwrap());
        assert_eq!(c, want_c, "{name}: correlation_into");

        let (want_kc, want_kr) = engine
            .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
            .unwrap()
            .expect("kernel");
        let mut resid = vec![f64::NAN; 3];
        assert!(engine
            .kkt_sweep_into(loss, &reg, &data.response, &eta, 0.5, &mut c, &mut resid)
            .unwrap());
        assert_eq!(c, want_kc, "{name}: kkt_sweep_into c");
        assert_eq!(resid, want_kr, "{name}: kkt_sweep_into resid");

        let want_b = engine
            .kkt_sweep_batch(loss, &reg, &data.response, &eta, &lambdas, 1.2)
            .unwrap()
            .expect("kernel");
        let mut batch = KktBatch::default();
        for round in 0..2 {
            // Round 2 reuses the filled buffers — the steady state.
            assert!(engine
                .kkt_sweep_batch_into(loss, &reg, &data.response, &eta, &lambdas, 1.2, &mut batch)
                .unwrap());
            assert_eq!(batch.c, want_b.c, "{name} round {round}: batch c");
            assert_eq!(batch.resid, want_b.resid, "{name} round {round}: batch resid");
            assert_eq!(batch.keep, want_b.keep, "{name} round {round}: keep-masks");
        }

        let (e, d) = (3usize, 2usize);
        let xe_t = &dense.data()[..e * n];
        let xd_t = &dense.data()[e * n..(e + d) * n];
        let want_g = engine
            .gram_block(xe_t, None, xd_t, e, d, n)
            .unwrap()
            .expect("kernel");
        let mut out = vec![f64::NAN; 1];
        assert!(engine.gram_block_into(xe_t, None, xd_t, e, d, n, &mut out).unwrap());
        assert_eq!(out, want_g, "{name}: gram_block_into");
    }
}

#[test]
fn upload_pipeline_is_observable() {
    let (n, p) = test_shape((40, 256), (12, 64));
    let shards = 4usize;
    let data = SyntheticSpec::new(n, p, 5).seed(59).generate();
    let dense = dense_of(&data);
    // Unsharded engines report no upload pipeline.
    assert!(RuntimeEngine::native().upload_stats().is_none());
    let engine = RuntimeEngine::native_sharded(shards, 1);
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    // A sweep blocks on every shard, so afterwards the pipeline has
    // fully drained and the counters must balance.
    let _ = engine.correlation(&reg, &data.response).unwrap().unwrap();
    let u = engine.upload_stats().expect("sharded engines expose stats");
    assert_eq!(u.staged, shards);
    assert_eq!(u.uploaded, shards);
    assert!(u.overlapped <= shards - 1, "only the pipelined shards can overlap");
    assert!(u.stage_seconds >= 0.0 && u.upload_seconds >= 0.0 && u.stall_seconds >= 0.0);
    // Out-of-core instrumentation: staging read every design byte
    // exactly once, the drained pipeline holds nothing in flight, and
    // at no instant were more than two shard panels resident.
    assert_eq!(u.bytes_read, (8 * n * p) as u64, "one pass over the design");
    assert!(u.read_seconds >= 0.0 && u.read_seconds <= u.stage_seconds + 1e-9);
    assert_eq!(u.inflight_bytes, 0, "drained pipeline still holds staged bytes");
    let chunk = (p + shards - 1) / shards;
    assert_eq!(u.max_panel_bytes, (8 * n * chunk) as u64, "panel = one shard");
    assert!(u.max_panel_bytes < (8 * n * p) as u64, "never a full n×p panel");
    assert!(
        u.peak_inflight_bytes >= u.max_panel_bytes
            && u.peak_inflight_bytes <= 2 * u.max_panel_bytes,
        "peak in-flight {} outside [1, 2] panels of {}",
        u.peak_inflight_bytes,
        u.max_panel_bytes
    );
}
