//! Predictor screening rules.
//!
//! Every rule here is expressed in the paper's §3 "gradient estimate"
//! framing: a rule builds an estimate c̃(λ_{k+1}) of the correlation
//! vector at the next path step and discards predictor j when
//! |c̃_j| < λ_{k+1} (eq. 4). The Hessian rule (§3.3) is the paper's
//! contribution; the others are the baselines of §1/§4 and Appendix F.6:
//!
//! * [`strong_set`] — the sequential strong rule (unit bound, eq. 5);
//! * [`hessian_screen`] — the Hessian Screening Rule (eq. 6 + the
//!   strong-restriction and γ adjustments of §3.3);
//! * [`gap_safe_keep`] — Gap Safe sphere test (§3.3.4 / Fercoq et al.);
//! * [`lookahead_keep`] — *batched look-ahead* Gap-Safe masks: from a
//!   single correlation sweep at the λ_k solution, the sphere test is
//!   evaluated at several upcoming values λ_{k+1..k+B} at once, so the
//!   path driver can pre-screen those steps and skip their full-set
//!   KKT sweeps entirely (Larsson, *Look-Ahead Screening Rules for the
//!   Lasso*, 2021, arXiv:2105.05648). The batched kernel behind it is
//!   [`crate::runtime::Backend::kkt_sweep_batch`], consumed through
//!   [`crate::runtime::EngineSweep::look_ahead`];
//! * [`edpp_keep`] — Enhanced Dual Polytope Projection (lasso only);
//! * [`sasvi_keep`] — (Dynamic) Sasvi ball test;
//! * working sets / Celer / Blitz are *strategies* layered on these
//!   estimates and live in the path driver (`crate::path`).

#![forbid(unsafe_code)]

use crate::linalg::Design;

/// Which screening strategy a path fit uses. `Working` is the paper's
/// "working+" (working-set strategy augmented with Gap-Safe checks,
/// §3.3.4); `None` disables screening (every predictor always enters
/// the subproblem).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScreeningKind {
    Hessian,
    Strong,
    Working,
    Celer,
    Blitz,
    GapSafe,
    Edpp,
    Sasvi,
    None,
}

impl ScreeningKind {
    pub fn name(self) -> &'static str {
        match self {
            ScreeningKind::Hessian => "hessian",
            ScreeningKind::Strong => "strong",
            ScreeningKind::Working => "working",
            ScreeningKind::Celer => "celer",
            ScreeningKind::Blitz => "blitz",
            ScreeningKind::GapSafe => "gap_safe",
            ScreeningKind::Edpp => "edpp",
            ScreeningKind::Sasvi => "sasvi",
            ScreeningKind::None => "none",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "hessian" => ScreeningKind::Hessian,
            "strong" => ScreeningKind::Strong,
            "working" | "working+" | "working_plus" => ScreeningKind::Working,
            "celer" => ScreeningKind::Celer,
            "blitz" => ScreeningKind::Blitz,
            "gap_safe" | "gapsafe" => ScreeningKind::GapSafe,
            "edpp" => ScreeningKind::Edpp,
            "sasvi" => ScreeningKind::Sasvi,
            "none" => ScreeningKind::None,
            _ => return None,
        })
    }

    /// All strategies, in the order used by the experiment harness.
    pub fn all() -> [ScreeningKind; 9] {
        [
            ScreeningKind::Hessian,
            ScreeningKind::Strong,
            ScreeningKind::Working,
            ScreeningKind::Celer,
            ScreeningKind::Blitz,
            ScreeningKind::GapSafe,
            ScreeningKind::Edpp,
            ScreeningKind::Sasvi,
            ScreeningKind::None,
        ]
    }
}

impl std::fmt::Display for ScreeningKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Sequential strong rule (eq. 5): keep j iff
/// |c(λ_k)_j| ≥ 2λ_{k+1} − λ_k. Active predictors always satisfy this
/// (|c_j| = λ_k ≥ 2λ_{k+1} − λ_k whenever λ_{k+1} ≤ λ_k).
pub fn strong_set(c_prev: &[f64], lambda_prev: f64, lambda_next: f64) -> Vec<usize> {
    let thr = 2.0 * lambda_next - lambda_prev;
    c_prev
        .iter()
        .enumerate()
        .filter(|(_, c)| c.abs() >= thr)
        .map(|(j, _)| j)
        .collect()
}

/// Strong-rule *membership* test for a single predictor.
#[inline]
pub fn strong_keeps(c_prev_j: f64, lambda_prev: f64, lambda_next: f64) -> bool {
    c_prev_j.abs() >= 2.0 * lambda_next - lambda_prev
}

/// The Hessian Screening Rule (§3.3). Inputs:
/// * `c_prev` — the full correlation vector c(λ_k) at the solved step;
/// * `u` — the n-vector D(w)·X_A·(X_AᵀD(w)X_A)⁻¹·sign(β̂_A) computed by
///   the path driver from the Hessian tracker (the expensive inner
///   products against all of X are restricted to the strong set below,
///   exactly as in the paper's modification);
/// * `active_prev` — A(λ_k); `gamma` — the unit-bound mixin (0.01).
///
/// Returns the screened (kept) set; the caller unions it with the
/// ever-active set (§3.3 "one more modification").
#[allow(clippy::too_many_arguments)]
pub fn hessian_screen<D: Design + ?Sized>(
    design: &D,
    c_prev: &[f64],
    u: &[f64],
    active_prev: &[usize],
    lambda_prev: f64,
    lambda_next: f64,
    gamma: f64,
) -> Vec<usize> {
    let p = design.ncols();
    let dl = lambda_next - lambda_prev; // negative along the path
    let mut keep = Vec::with_capacity(active_prev.len() * 2 + 8);
    let mut is_active = vec![false; p];
    for &j in active_prev {
        is_active[j] = true;
    }
    for j in 0..p {
        if is_active[j] {
            // c̃_j = λ_{k+1}·sign(β̂_j): exactly at the boundary — kept.
            keep.push(j);
            continue;
        }
        if !strong_keeps(c_prev[j], lambda_prev, lambda_next) {
            // Outside the strong set: assumed inactive (c̃_j = 0).
            continue;
        }
        // Second-order estimate (eq. 6) + γ·unit-bound upward bias.
        let est = c_prev[j] + dl * design.col_dot(j, u) + gamma * (-dl) * c_prev[j].signum();
        if est.abs() >= lambda_next {
            keep.push(j);
        }
    }
    keep
}

/// Gap Safe sphere test: keep j iff
/// |xⱼᵀθ| ≥ 1 − ‖xⱼ‖·√(2G/λ²) (§3.3.4). `xt_theta` may be restricted
/// to a candidate set; `cols[i]` names the predictor behind
/// `xt_theta[i]`. Returns the kept subset of `cols`.
pub fn gap_safe_keep(
    xt_theta: &[f64],
    cols: &[usize],
    col_norms: &[f64],
    gap: f64,
    lambda: f64,
) -> Vec<usize> {
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    cols.iter()
        .zip(xt_theta)
        .filter(|(&j, &xt)| xt.abs() >= 1.0 - col_norms[j] * radius)
        .map(|(&j, _)| j)
        .collect()
}

/// Look-ahead Gap-Safe mask (Larsson 2021, arXiv:2105.05648): given
/// the correlation vector c = Xᵀresid and its sup-norm at the current
/// iterate, plus the duality gap evaluated at a *future* λ, returns
/// `keep[j] = |xⱼᵀθ| ≥ 1 − ‖xⱼ‖·√(2G(λ))/λ − slack` with
/// θ = resid/max(λ, ‖c‖∞). `keep[j] == false` certifies β*ⱼ(λ) = 0 —
/// the sphere is safe for any feasible dual point, so one sweep yields
/// valid masks for a whole batch of upcoming λ values. `slack` (0 for
/// exact-f64 correlations) loosens the threshold for reduced-precision
/// backends: entries trusted only to within `slack·scale` can then be
/// conservatively kept, never wrongly discarded
/// ([`crate::runtime::EngineSweep::look_ahead`] passes its
/// `recheck_band`).
pub fn lookahead_keep(
    c: &[f64],
    col_norms: &[f64],
    xt_inf: f64,
    gap: f64,
    lambda: f64,
    slack: f64,
) -> Vec<bool> {
    let mut keep = Vec::new();
    lookahead_keep_into(c, col_norms, xt_inf, gap, lambda, slack, &mut keep);
    keep
}

/// Allocation-free twin of [`lookahead_keep`]: writes the mask into a
/// caller-owned buffer (cleared first) so the steady-state path loop
/// can reuse mask storage across look-ahead batches.
pub fn lookahead_keep_into(
    c: &[f64],
    col_norms: &[f64],
    xt_inf: f64,
    gap: f64,
    lambda: f64,
    slack: f64,
    keep: &mut Vec<bool>,
) {
    let scale = lambda.max(xt_inf);
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    keep.clear();
    keep.extend(
        c.iter()
            .zip(col_norms)
            .map(|(cj, nj)| cj.abs() / scale >= 1.0 - nj * radius - slack),
    );
}

/// EDPP (Enhanced Dual Polytope Projection), sequential, for the
/// ordinary lasso only. Given the previous dual optimum
/// θ_prev = r(λ_k)/λ_k:
///
///   v1 = y/λ_k − θ_prev                       (λ_k < λ_max)
///   v1 = sign(x_{j*}ᵀy)·x_{j*}                (λ_k = λ_max)
///   v2 = y/λ_{k+1} − θ_prev
///   v2⊥ = v2 − (⟨v1,v2⟩/‖v1‖²)·v1
///   keep j ⇔ |xⱼᵀ(θ_prev + v2⊥/2)| ≥ 1 − ‖xⱼ‖·‖v2⊥‖/2.
///
/// As the paper notes (§1), sequential EDPP is only *safe in practice*
/// when θ_prev is exact; with iterative solvers it behaves heuristically
/// — we therefore pair it with KKT checks like every other rule.
#[allow(clippy::too_many_arguments)]
pub fn edpp_keep<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    theta_prev: &[f64],
    lambda_prev: f64,
    lambda_next: f64,
    at_lambda_max: bool,
    argmax_col: usize,
    col_norms: &[f64],
) -> Vec<usize> {
    let n = y.len();
    let mut v1 = vec![0.0; n];
    if at_lambda_max {
        // v1 = sign(x_{j*}ᵀ y) · x_{j*}
        design.col_axpy(argmax_col, 1.0, &mut v1);
        let s = design.col_dot(argmax_col, y).signum();
        for v in v1.iter_mut() {
            *v *= s;
        }
    } else {
        for i in 0..n {
            v1[i] = y[i] / lambda_prev - theta_prev[i];
        }
    }
    let mut v2 = vec![0.0; n];
    for i in 0..n {
        v2[i] = y[i] / lambda_next - theta_prev[i];
    }
    let v1v2 = crate::linalg::blas::dot(&v1, &v2);
    let v1n = crate::linalg::blas::sq_norm(&v1);
    let coef = if v1n > 0.0 { v1v2 / v1n } else { 0.0 };
    // v2⊥ and the test center θ_prev + v2⊥/2 fused into one vector.
    let mut center = vec![0.0; n];
    let mut v2p_sq = 0.0;
    for i in 0..n {
        let v2p = v2[i] - coef * v1[i];
        v2p_sq += v2p * v2p;
        center[i] = theta_prev[i] + 0.5 * v2p;
    }
    let half_norm = 0.5 * v2p_sq.sqrt();
    let p = design.ncols();
    let mut keep = Vec::new();
    for j in 0..p {
        let t = design.col_dot(j, &center).abs();
        if t >= 1.0 - col_norms[j] * half_norm {
            keep.push(j);
        }
    }
    keep
}

/// Sasvi ball test for the lasso. The Sasvi safe region is
/// {θ : ⟨θ − θ₀, θ − y/λ⟩ ≤ 0} — the ball with diameter from the
/// feasible dual point θ₀ to y/λ. Keep j iff
/// |xⱼᵀc| + r‖xⱼ‖ ≥ 1 with c = (θ₀ + y/λ)/2, r = ‖y/λ − θ₀‖/2.
/// ("Dynamic" = re-applied with the current θ₀ at every outer check;
/// the half-space refinement of the full dome is omitted — the ball is
/// still safe, just slightly larger. DESIGN.md §3 documents this.)
pub fn sasvi_keep<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    theta0: &[f64],
    lambda: f64,
    col_norms: &[f64],
) -> Vec<usize> {
    let n = y.len();
    let mut center = vec![0.0; n];
    let mut diam_sq = 0.0;
    for i in 0..n {
        let yl = y[i] / lambda;
        center[i] = 0.5 * (theta0[i] + yl);
        let d = yl - theta0[i];
        diam_sq += d * d;
    }
    let r = 0.5 * diam_sq.sqrt();
    let p = design.ncols();
    let mut keep = Vec::new();
    for j in 0..p {
        if design.col_dot(j, &center).abs() + r * col_norms[j] >= 1.0 {
            keep.push(j);
        }
    }
    keep
}

/// Working-set priority used by Blitz and Celer: the normalized distance
/// of predictor j's dual constraint from the current dual point,
/// d_j = (1 − |xⱼᵀθ|)/‖xⱼ‖. Smaller = more likely active.
#[inline]
pub fn ws_priority(xt_theta_j: f64, col_norm_j: f64) -> f64 {
    (1.0 - xt_theta_j.abs()) / col_norm_j.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DesignMatrix;
    use crate::testkit::Gen;

    #[test]
    fn kind_parse_roundtrip() {
        for k in ScreeningKind::all() {
            assert_eq!(ScreeningKind::parse(k.name()), Some(k));
        }
        assert_eq!(ScreeningKind::parse("working+"), Some(ScreeningKind::Working));
        assert_eq!(ScreeningKind::parse("Gap-Safe"), Some(ScreeningKind::GapSafe));
        assert_eq!(ScreeningKind::parse("bogus"), None);
        assert_eq!(format!("{}", ScreeningKind::Hessian), "hessian");
    }

    #[test]
    fn strong_rule_threshold() {
        let c = vec![0.9, 0.5, -0.95, 0.1];
        // λ_k = 1, λ_{k+1} = 0.9 ⇒ threshold 0.8
        let s = strong_set(&c, 1.0, 0.9);
        assert_eq!(s, vec![0, 2]);
        assert!(strong_keeps(0.8, 1.0, 0.9));
        assert!(!strong_keeps(0.79, 1.0, 0.9));
    }

    #[test]
    fn strong_rule_keeps_active() {
        // active predictors have |c| = λ_k which always passes
        assert!(strong_keeps(1.0, 1.0, 0.5));
        assert!(strong_keeps(-1.0, 1.0, 0.999));
    }

    #[test]
    fn hessian_screen_exact_when_no_active_change() {
        // Remark 3.2: with u built from the true Hessian, the estimate is
        // exact for the next step if the active set is unchanged; here we
        // check the mechanical behaviour: active are always kept, weak
        // correlations dropped.
        let mut g = Gen::new(3);
        let x = DesignMatrix::Dense(g.gaussian_matrix(20, 6));
        let u = vec![0.0; 20]; // no second-order correction
        let c_prev = vec![1.0, 0.95, 0.5, -0.99, 0.2, -0.6];
        let keep = hessian_screen(&x, &c_prev, &u, &[0], 1.0, 0.9, 0.0);
        // j=0 active → kept. Strong threshold 0.8: j∈{1,3} pass strong;
        // estimate = c_prev (u = 0, γ = 0): |0.95| ≥ 0.9 keep, |−0.99| keep.
        assert_eq!(keep, vec![0, 1, 3]);
    }

    #[test]
    fn hessian_screen_gamma_biases_upward() {
        let mut g = Gen::new(4);
        let x = DesignMatrix::Dense(g.gaussian_matrix(10, 3));
        let u = vec![0.0; 10];
        // c = 0.895 < λnext = 0.9, strong keeps (0.895 ≥ 0.8).
        let c_prev = vec![0.895, 0.0, 0.0];
        let no_gamma = hessian_screen(&x, &c_prev, &u, &[], 1.0, 0.9, 0.0);
        assert!(no_gamma.is_empty());
        // γ = 0.1: est = 0.895 + 0.1·0.1 = 0.905 ≥ 0.9 → kept.
        let with_gamma = hessian_screen(&x, &c_prev, &u, &[], 1.0, 0.9, 0.1);
        assert_eq!(with_gamma, vec![0]);
    }

    #[test]
    fn gap_safe_zero_gap_keeps_only_boundary() {
        // gap = 0 ⇒ radius 0 ⇒ keep only |xᵀθ| ≥ 1.
        let xt = vec![1.0, 0.99, -1.0];
        let cols = vec![0, 1, 2];
        let norms = vec![1.0, 1.0, 1.0];
        let keep = gap_safe_keep(&xt, &cols, &norms, 0.0, 0.5);
        assert_eq!(keep, vec![0, 2]);
    }

    #[test]
    fn gap_safe_large_gap_keeps_everything() {
        let xt = vec![0.0, 0.1];
        let cols = vec![0, 1];
        let norms = vec![1.0, 1.0];
        let keep = gap_safe_keep(&xt, &cols, &norms, 100.0, 0.5);
        assert_eq!(keep, vec![0, 1]);
    }

    #[test]
    fn lookahead_mask_agrees_with_gap_safe_keep() {
        // The look-ahead mask is the same sphere test, evaluated at a
        // future λ from the current c: cross-check against
        // gap_safe_keep on the scaled correlations.
        let c = vec![0.95, 0.40, -0.99, 0.05];
        let norms = vec![1.0, 0.8, 1.2, 1.0];
        let (xt_inf, gap, lambda) = (0.99, 1e-4, 0.9);
        let mask = lookahead_keep(&c, &norms, xt_inf, gap, lambda, 0.0);
        let scale = lambda.max(xt_inf);
        let xt_theta: Vec<f64> = c.iter().map(|v| v / scale).collect();
        let cols: Vec<usize> = (0..c.len()).collect();
        let kept = gap_safe_keep(&xt_theta, &cols, &norms, gap, lambda);
        for j in 0..c.len() {
            assert_eq!(mask[j], kept.contains(&j), "col {j}");
        }
    }

    #[test]
    fn lookahead_mask_widens_as_lambda_recedes() {
        // Farther-ahead λ values have larger gaps at the frozen
        // iterate, so their masks can only keep more predictors.
        let mut g = Gen::new(8);
        let x = DesignMatrix::Dense(g.gaussian_matrix(30, 12));
        let y = g.gaussian_vec(30);
        use crate::linalg::Design;
        let c: Vec<f64> = (0..12).map(|j| x.col_dot(j, &y)).collect();
        let norms: Vec<f64> = (0..12).map(|j| x.col_sq_norm(j).sqrt()).collect();
        let xt_inf = c.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let near = lookahead_keep(&c, &norms, xt_inf, 0.01, 0.9 * xt_inf, 0.0);
        let far = lookahead_keep(&c, &norms, xt_inf, 0.5, 0.6 * xt_inf, 0.0);
        let n_near = near.iter().filter(|&&k| k).count();
        let n_far = far.iter().filter(|&&k| k).count();
        assert!(n_far >= n_near, "far mask kept {n_far} < near {n_near}");
    }

    #[test]
    fn edpp_at_lambda_max_discards_weak_predictors() {
        let mut g = Gen::new(5);
        let x = DesignMatrix::Dense(g.gaussian_matrix(30, 8));
        let y = g.gaussian_vec(30);
        use crate::linalg::Design;
        let norms: Vec<f64> = (0..8).map(|j| x.col_sq_norm(j).sqrt()).collect();
        let c: Vec<f64> = (0..8).map(|j| x.col_dot(j, &y)).collect();
        let (jmax, cmax) = c
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(j, c)| (j, c.abs()))
            .unwrap();
        let lmax = cmax;
        let theta = y.iter().map(|v| v / lmax).collect::<Vec<_>>();
        let keep = edpp_keep(&x, &y, &theta, lmax, 0.9 * lmax, true, jmax, &norms);
        // The argmax predictor must be kept; the set must not be all of p
        // for a reasonable step (EDPP has real discarding power just
        // below λmax).
        assert!(keep.contains(&jmax));
        assert!(keep.len() < 8, "kept {keep:?}");
    }

    #[test]
    fn sasvi_keeps_superset_of_boundary() {
        let mut g = Gen::new(6);
        let x = DesignMatrix::Dense(g.gaussian_matrix(25, 6));
        let y = g.gaussian_vec(25);
        use crate::linalg::Design;
        let norms: Vec<f64> = (0..6).map(|j| x.col_sq_norm(j).sqrt()).collect();
        let c: Vec<f64> = (0..6).map(|j| x.col_dot(j, &y)).collect();
        let lmax = c.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let theta: Vec<f64> = y.iter().map(|v| v / lmax).collect();
        // At λ = λmax, θ₀ = y/λ: the ball degenerates to a point and the
        // kept set is exactly {j : |xⱼᵀy|/λmax ≥ 1} = argmax set.
        let keep = sasvi_keep(&x, &y, &theta, lmax, &norms);
        assert_eq!(keep.len(), 1);
        // Just below λmax the ball inflates and keeps more.
        let lam = 0.8 * lmax;
        let theta2: Vec<f64> = y.iter().map(|v| v / lmax).collect();
        let keep2 = sasvi_keep(&x, &y, &theta2, lam, &norms);
        assert!(keep2.len() >= keep.len());
    }

    #[test]
    fn priority_ordering() {
        assert!(ws_priority(0.99, 1.0) < ws_priority(0.5, 1.0));
        assert!(ws_priority(-0.99, 1.0) < ws_priority(0.5, 1.0));
        // larger column norm ⇒ higher priority (smaller d)
        assert!(ws_priority(0.5, 2.0) < ws_priority(0.5, 1.0));
    }
}
