//! The pure-Rust compute backend: exact f64 kernels on top of
//! [`crate::linalg`]. This is the reference implementation of the
//! [`Backend`] surface — always available, no artifacts, no FFI — and
//! the baseline every accelerated backend is cross-checked against
//! (`rust/tests/runtime_roundtrip.rs`).
//!
//! Parallelism: the sweep and panel kernels are chunked
//! column-parallel over `std::thread::scope` (zero dependencies).
//! Each output entry is produced by the same per-column scalar kernel
//! regardless of thread count, so results are **bit-identical** to the
//! serial loop — threading is a pure wall-clock knob, never a
//! numerics knob.

#![forbid(unsafe_code)]

use super::{Backend, DesignRepr, KktBatch, RegisteredDesign};
use crate::error::Result;
use crate::linalg::blas;
use crate::loss::Loss;

/// Minimum multiply-add count before spawning threads pays for itself
/// (scope + spawn overhead is on the order of tens of microseconds).
const PAR_FLOP_CUTOFF: usize = 1 << 18;

/// ⌈a/b⌉ (usize::div_ceil needs Rust 1.73; MSRV is 1.70).
fn div_ceil(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

/// The pure-Rust backend. `threads` controls chunked column-parallel
/// execution of the sweep/panel kernels; 1 = serial.
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self { threads: 1 }
    }
}

/// The op kinds the native backend serves: xt_r, the fused KKT sweep
/// (Gaussian + logistic), the batched look-ahead sweep, and the
/// weighted Gram panel.
const NATIVE_OPS: usize = 4;

impl NativeBackend {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    fn column(data: &[f64], n: usize, j: usize) -> &[f64] {
        &data[j * n..(j + 1) * n]
    }

    fn design_data(design: &RegisteredDesign) -> Result<&[f64]> {
        match &design.repr {
            DesignRepr::Native(data) => Ok(data),
            _ => Err(crate::err!(
                "design was registered with a different backend"
            )),
        }
    }

    /// Worker count for `items` outputs of `flops_per_item` work each.
    fn pool_size(&self, items: usize, flops_per_item: usize) -> usize {
        if self.threads <= 1 || items.saturating_mul(flops_per_item) < PAR_FLOP_CUTOFF {
            1
        } else {
            self.threads.min(items.max(1))
        }
    }

    /// out[i] = f(i), contiguous chunks per thread. Bit-identical to
    /// the serial loop at any thread count.
    fn par_map(&self, out: &mut [f64], flops_per_item: usize, f: impl Fn(usize) -> f64 + Sync) {
        let t = self.pool_size(out.len(), flops_per_item);
        if t <= 1 {
            for (i, o) in out.iter_mut().enumerate() {
                *o = f(i);
            }
            return;
        }
        let chunk = div_ceil(out.len(), t);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (ci, co) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                handles.push(s.spawn(move || {
                    for (i, o) in co.iter_mut().enumerate() {
                        *o = f(ci * chunk + i);
                    }
                }));
            }
            for h in handles {
                h.join().expect("sweep worker panicked");
            }
        });
    }

    /// Row-blocked variant for row-major (rows, row_len) panels:
    /// `f(a, row)` fills row a. Bit-identical to the serial loop.
    fn par_map_rows(
        &self,
        rows: usize,
        row_len: usize,
        out: &mut [f64],
        flops_per_row: usize,
        f: impl Fn(usize, &mut [f64]) + Sync,
    ) {
        debug_assert_eq!(out.len(), rows * row_len);
        let t = self.pool_size(rows, flops_per_row);
        if t <= 1 {
            for (a, ro) in out.chunks_mut(row_len.max(1)).enumerate() {
                f(a, ro);
            }
            return;
        }
        let rows_per = div_ceil(rows, t);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (ci, co) in out.chunks_mut(rows_per * row_len).enumerate() {
                let f = &f;
                handles.push(s.spawn(move || {
                    for (i, ro) in co.chunks_mut(row_len).enumerate() {
                        f(ci * rows_per + i, ro);
                    }
                }));
            }
            for h in handles {
                h.join().expect("panel worker panicked");
            }
        });
    }

    fn check_vectors(design: &RegisteredDesign, y: &[f64], eta: &[f64]) -> Result<()> {
        if y.len() != design.n || eta.len() != design.n {
            return Err(crate::err!(
                "y/eta have lengths {}/{}, expected {}",
                y.len(),
                eta.len(),
                design.n
            ));
        }
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn num_ops(&self) -> usize {
        NATIVE_OPS
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn supports_sweep(&self, loss: Loss, _n: usize, _p: usize) -> bool {
        // Shape-agnostic: the native kernels are not compiled per shape.
        // Poisson is excluded to mirror the artifact surface (no
        // Lipschitz gradient, no fused sweep — paper App. F.9).
        !matches!(loss, Loss::Poisson)
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        if col_major.len() != n * p {
            return Err(crate::err!(
                "design buffer has {} entries, expected {}x{}",
                col_major.len(),
                n,
                p
            ));
        }
        let col_norms = (0..p)
            .map(|j| blas::nrm2(Self::column(col_major, n, j)))
            .collect();
        Ok(RegisteredDesign {
            n,
            p,
            col_norms,
            repr: DesignRepr::Native(col_major.to_vec()),
        })
    }

    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let data = Self::design_data(design)?;
        if r.len() != design.n {
            return Err(crate::err!(
                "residual has length {}, expected {}",
                r.len(),
                design.n
            ));
        }
        let mut c = vec![0.0; design.p];
        self.par_map(&mut c, design.n, |j| {
            blas::dot(Self::column(data, design.n, j), r)
        });
        Ok(Some(c))
    }

    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        _lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        if matches!(loss, Loss::Poisson) {
            return Ok(None);
        }
        let data = Self::design_data(design)?;
        Self::check_vectors(design, y, eta)?;
        let mut resid = vec![0.0; design.n];
        loss.pseudo_residual_into(y, eta, &mut resid);
        let mut c = vec![0.0; design.p];
        let r = &resid;
        self.par_map(&mut c, design.n, |j| {
            blas::dot(Self::column(data, design.n, j), r)
        });
        Ok(Some((c, resid)))
    }

    fn kkt_sweep_batch(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        if matches!(loss, Loss::Poisson) || lambdas.is_empty() {
            return Ok(None);
        }
        let data = Self::design_data(design)?;
        Self::check_vectors(design, y, eta)?;
        let mut resid = vec![0.0; design.n];
        loss.pseudo_residual_into(y, eta, &mut resid);
        let mut c = vec![0.0; design.p];
        let r = &resid;
        self.par_map(&mut c, design.n, |j| {
            blas::dot(Self::column(data, design.n, j), r)
        });
        // One sweep, B masks: the per-λ sphere tests reuse c (Larsson
        // 2021 — the O(pB) mask pass is marginal next to the O(np)
        // sweep it amortizes).
        let xt_inf = blas::amax(&c);
        let keep = lambdas
            .iter()
            .map(|&l| {
                let gap = loss.duality_gap(y, eta, &resid, xt_inf, l, l1_norm);
                crate::screening::lookahead_keep(&c, &design.col_norms, xt_inf, gap, l, 0.0)
            })
            .collect();
        Ok(Some(KktBatch { c, resid, keep }))
    }

    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        if xe_t.len() != e * n || xd_t.len() != d * n || w.is_some_and(|w| w.len() != n) {
            return Err(crate::err!(
                "gram_block shape mismatch: xe {}, xd {}, w {} for (e={e}, d={d}, n={n})",
                xe_t.len(),
                xd_t.len(),
                w.map_or(n, <[f64]>::len)
            ));
        }
        if e * d == 0 {
            return Ok(Some(Vec::new()));
        }
        // Row-major (e, d) panel: out[a*d + b] = Σ_i xe[a,i] w[i] xd[b,i].
        let mut out = vec![0.0; e * d];
        self.par_map_rows(e, d, &mut out, d * n, |a, row| {
            let xa = &xe_t[a * n..(a + 1) * n];
            for (b, o) in row.iter_mut().enumerate() {
                let xb = &xd_t[b * n..(b + 1) * n];
                *o = match w {
                    None => blas::dot(xa, xb),
                    Some(w) => blas::dot_w(xa, xb, w),
                };
            }
        });
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DenseMatrix, Design};
    use crate::testkit::Gen;

    #[test]
    fn register_rejects_bad_shape() {
        let b = NativeBackend::default();
        assert!(b.register_design(&[1.0, 2.0, 3.0], 2, 2).is_err());
    }

    #[test]
    fn register_caches_column_norms() {
        let mut g = Gen::new(4);
        let m = g.gaussian_matrix(17, 6);
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), 17, 6).unwrap();
        for j in 0..6 {
            assert_eq!(reg.col_norms[j], m.col_sq_norm(j).sqrt(), "col {j}");
        }
    }

    #[test]
    fn kkt_sweep_matches_pseudo_residual_path() {
        let mut g = Gen::new(5);
        let m = g.gaussian_matrix(25, 10);
        let y = g.gaussian_vec(25);
        let eta = g.gaussian_vec(25);
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), 25, 10).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let (c, resid) = b.kkt_sweep(loss, &reg, &y, &eta, 0.7).unwrap().unwrap();
            let mut resid_ref = vec![0.0; 25];
            loss.pseudo_residual_into(&y, &eta, &mut resid_ref);
            for i in 0..25 {
                assert!((resid[i] - resid_ref[i]).abs() < 1e-14);
            }
            for j in 0..10 {
                assert!((c[j] - m.col_dot(j, &resid_ref)).abs() < 1e-12);
            }
        }
        assert!(b.kkt_sweep(Loss::Poisson, &reg, &y, &eta, 0.7).unwrap().is_none());
    }

    #[test]
    fn threaded_kernels_are_bit_identical() {
        // Shape large enough to clear the flop cutoff so threads
        // actually spawn.
        let (n, p) = (64, 8_192);
        let mut g = Gen::new(21);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let eta = g.gaussian_vec(n);
        let serial = NativeBackend::default();
        let par = NativeBackend::new(4);
        assert_eq!(par.threads(), 4);
        let rs = serial.register_design(m.data(), n, p).unwrap();
        let rp = par.register_design(m.data(), n, p).unwrap();
        let cs = serial.correlation(&rs, &y).unwrap().unwrap();
        let cp = par.correlation(&rp, &y).unwrap().unwrap();
        assert_eq!(cs, cp, "threaded correlation must be bit-identical");
        let (ks, _) = serial.kkt_sweep(Loss::Logistic, &rs, &y, &eta, 0.5).unwrap().unwrap();
        let (kp, _) = par.kkt_sweep(Loss::Logistic, &rp, &y, &eta, 0.5).unwrap().unwrap();
        assert_eq!(ks, kp, "threaded kkt_sweep must be bit-identical");
    }

    #[test]
    fn batch_matches_per_lambda_sweeps() {
        let (n, p) = (40, 120);
        let mut g = Gen::new(9);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let eta = vec![0.0; n];
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), n, p).unwrap();
        let lambdas = [0.9, 0.7, 0.5];
        let batch = b
            .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &lambdas, 0.0)
            .unwrap()
            .unwrap();
        assert_eq!(batch.keep.len(), 3);
        let (c_seq, resid_seq) = b
            .kkt_sweep(Loss::Gaussian, &reg, &y, &eta, 0.9)
            .unwrap()
            .unwrap();
        assert_eq!(batch.c, c_seq, "batched c must equal the per-λ sweep");
        assert_eq!(batch.resid, resid_seq);
        // Masks match a direct evaluation of the sphere test.
        let xt_inf = blas::amax(&batch.c);
        for (l, &lam) in lambdas.iter().enumerate() {
            let gap = Loss::Gaussian.duality_gap(&y, &eta, &batch.resid, xt_inf, lam, 0.0);
            let want =
                crate::screening::lookahead_keep(&batch.c, &reg.col_norms, xt_inf, gap, lam, 0.0);
            assert_eq!(batch.keep[l], want, "mask {l}");
        }
        // Poisson and empty batches are unavailable, not errors.
        assert!(b
            .kkt_sweep_batch(Loss::Poisson, &reg, &y, &eta, &lambdas, 0.0)
            .unwrap()
            .is_none());
        assert!(b
            .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &[], 0.0)
            .unwrap()
            .is_none());
    }

    #[test]
    fn gram_block_matches_weighted_gram() {
        let (e, d, n) = (4, 3, 20);
        let mut g = Gen::new(6);
        let m: DenseMatrix = g.gaussian_matrix(n, e + d);
        let w: Vec<f64> = (0..n).map(|i| 0.1 + (i % 3) as f64 * 0.4).collect();
        let mut xe_t = Vec::with_capacity(e * n);
        for j in 0..e {
            xe_t.extend_from_slice(m.col(j));
        }
        let mut xd_t = Vec::with_capacity(d * n);
        for j in e..e + d {
            xd_t.extend_from_slice(m.col(j));
        }
        let b = NativeBackend::default();
        let panel = b.gram_block(&xe_t, Some(&w), &xd_t, e, d, n).unwrap().unwrap();
        for a in 0..e {
            for bb in 0..d {
                let want = m.gram_weighted(a, e + bb, Some(&w));
                assert!(
                    (panel[a * d + bb] - want).abs() < 1e-12,
                    "panel ({a},{bb})"
                );
            }
        }
        // Unweighted panels use the plain dot kernel — bit-identical
        // to Design::gram.
        let unw = b.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        for a in 0..e {
            for bb in 0..d {
                assert_eq!(unw[a * d + bb], m.gram(a, e + bb), "unweighted ({a},{bb})");
            }
        }
        assert!(b.gram_block(&xe_t, Some(&w), &xd_t, e, d, n + 1).is_err());
        assert_eq!(
            b.gram_block(&[], None, &xd_t, 0, d, n).unwrap().unwrap(),
            Vec::<f64>::new()
        );
    }

    #[test]
    fn threaded_gram_block_is_bit_identical() {
        let (e, d, n) = (96, 64, 50);
        let mut g = Gen::new(13);
        let m: DenseMatrix = g.gaussian_matrix(n, e + d);
        let mut xe_t = Vec::with_capacity(e * n);
        for j in 0..e {
            xe_t.extend_from_slice(m.col(j));
        }
        let mut xd_t = Vec::with_capacity(d * n);
        for j in e..e + d {
            xd_t.extend_from_slice(m.col(j));
        }
        let serial = NativeBackend::default();
        let par = NativeBackend::new(3);
        let a = serial.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        let b = par.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        assert_eq!(a, b);
    }
}
