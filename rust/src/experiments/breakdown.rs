//! Appendix F.10 (Figures 12–14): runtime breakdown along the path —
//! how much of each step goes to coordinate descent, KKT checks,
//! Hessian updates and screening, for the e2006-tfidf, madelon and
//! rcv1 analogues, Hessian vs working+.

use super::*;
use crate::data::dataset_by_name;
use crate::metrics::{sig_figs, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let mut table = Table::new(&[
        "Dataset", "Method", "CD (s)", "KKT (s)", "Hessian (s)", "Screen (s)", "Total (s)",
    ]);
    let mut series =
        String::from("dataset,method,step,lambda,t_cd,t_kkt,t_hessian,t_screen,active\n");
    for name in ["e2006-tfidf", "madelon", "rcv1"] {
        let mut spec = dataset_by_name(name).ok_or("unknown dataset")?;
        if !cfg.full {
            spec.n = (spec.n / 4).max(100);
            spec.p = (spec.p / 4).max(100);
        }
        let data = spec.generate(0);
        for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
            let (fit, secs) = fit_timed(&data, kind, &paper_settings());
            let sum = |f: fn(&crate::path::StepStats) -> f64| -> f64 {
                fit.steps.iter().map(f).sum()
            };
            table.row(vec![
                name.into(),
                kind.name().into(),
                format!("{}", sig_figs(sum(|s| s.t_cd), 3)),
                format!("{}", sig_figs(sum(|s| s.t_kkt), 3)),
                format!("{}", sig_figs(sum(|s| s.t_hessian), 3)),
                format!("{}", sig_figs(sum(|s| s.t_screen), 3)),
                format!("{}", sig_figs(secs, 3)),
            ]);
            for (k, s) in fit.steps.iter().enumerate() {
                series.push_str(&format!(
                    "{name},{},{k},{:.6e},{:.6},{:.6},{:.6},{:.6},{}\n",
                    kind.name(),
                    s.lambda,
                    s.t_cd,
                    s.t_kkt,
                    s.t_hessian,
                    s.t_screen,
                    s.active
                ));
            }
        }
    }
    println!("\nFigures 12–14 — runtime breakdown along the path");
    println!("{}", table.render());
    write_csv(cfg, "fig12_breakdown", &table);
    write_text(cfg, "fig12_series.csv", &series);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_timers_cover_most_of_total() {
        let data = simulate(100, 600, 6, 0.4, 2.0, Loss::Gaussian, 14);
        let (fit, secs) = fit_timed(&data, ScreeningKind::Hessian, &paper_settings());
        let tracked: f64 = fit
            .steps
            .iter()
            .map(|s| s.t_cd + s.t_kkt + s.t_hessian + s.t_screen)
            .sum();
        assert!(tracked <= secs * 1.01, "tracked {tracked} > total {secs}");
        assert!(
            tracked >= secs * 0.4,
            "timers only cover {:.0}% of the fit",
            100.0 * tracked / secs
        );
    }

    #[test]
    fn working_spends_no_hessian_time() {
        let data = simulate(60, 300, 5, 0.4, 2.0, Loss::Gaussian, 15);
        let (fit, _) = fit_timed(&data, ScreeningKind::Working, &paper_settings());
        let th: f64 = fit.steps.iter().map(|s| s.t_hessian).sum();
        assert_eq!(th, 0.0);
    }
}
