//! Integration: the register-blocked panel kernels are bit-identical
//! to their scalar references — across block widths, ragged column
//! counts, and thread counts.
//!
//! The accumulation-order contract (linalg/blas.rs): a blocked kernel
//! replays the scalar kernel's exact mul_add sequence per column, so
//! neither the block width nor a thread-chunk boundary may change a
//! single bit. Everything here asserts `==` on f64 outputs, never
//! tolerance — the same bar the shard-equivalence suite holds, and
//! the reason `--threads`/`--shards` stay pure wall-clock knobs.
//! `make test-paranoid` runs this suite with the runtime invariant
//! layer compiled in.

mod common;

use common::test_shape;
use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::blas;
use hessian_screening::loss::Loss;
use hessian_screening::path::PathFitter;
use hessian_screening::rng::Xoshiro256pp;
use hessian_screening::runtime::RuntimeEngine;
use hessian_screening::screening::ScreeningKind;

/// Thread counts every test sweeps: serial, and past the native
/// backend's chunking so panel boundaries land mid-block.
const THREADS: [usize; 2] = [1, 4];

fn gaussian(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0; n];
    rng.fill_gaussian(&mut v);
    v
}

fn dense_of(data: &hessian_screening::data::Dataset) -> &hessian_screening::linalg::DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

#[test]
fn dot_block_matches_scalar_at_widths_1_2_4_8() {
    // Vector lengths straddling the 8-lane chunking: remainder tails
    // of every size, plus the empty product.
    for n in [0usize, 1, 7, 8, 9, 16, 63, 64, 65, 200] {
        let y = gaussian(n, 11);
        let cols: Vec<Vec<f64>> = (0..8).map(|j| gaussian(n, 100 + j as u64)).collect();
        let want: Vec<f64> = cols.iter().map(|c| blas::dot(c, &y)).collect();
        let c = |j: usize| cols[j].as_slice();
        assert_eq!(blas::dot_block::<1>([c(0)], &y), [want[0]], "B=1 n={n}");
        assert_eq!(
            blas::dot_block::<2>([c(0), c(1)], &y),
            [want[0], want[1]],
            "B=2 n={n}"
        );
        assert_eq!(
            blas::dot_block::<4>([c(0), c(1), c(2), c(3)], &y),
            [want[0], want[1], want[2], want[3]],
            "B=4 n={n}"
        );
        assert_eq!(
            blas::dot_block::<8>([c(0), c(1), c(2), c(3), c(4), c(5), c(6), c(7)], &y)
                .as_slice(),
            want.as_slice(),
            "B=8 n={n}"
        );
    }
}

#[test]
fn panels_match_scalar_loops_at_ragged_column_counts() {
    // Column counts ragged against PANEL_BLOCK = 4: full blocks, a
    // lone tail, and everything between.
    let n = 33;
    let x = gaussian(n, 3);
    let w: Vec<f64> = gaussian(n, 4).iter().map(|v| v.abs() + 0.1).collect();
    for cols in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 13] {
        let panel: Vec<f64> = (0..cols)
            .flat_map(|j| gaussian(n, 40 + j as u64))
            .collect();
        let mut got = vec![f64::NAN; cols];
        blas::dot_panel(&panel, n, &x, &mut got);
        let want: Vec<f64> = (0..cols)
            .map(|j| blas::dot(&panel[j * n..(j + 1) * n], &x))
            .collect();
        assert_eq!(got, want, "dot_panel cols={cols}");

        let mut got_w = vec![f64::NAN; cols];
        blas::dot_w_panel(&panel, n, &x, &w, &mut got_w);
        // dot_w streams `x` in its first slot: w·x rounds once before
        // meeting the column (the non-commutative direction).
        let want_w: Vec<f64> = (0..cols)
            .map(|j| blas::dot_w(&x, &panel[j * n..(j + 1) * n], &w))
            .collect();
        assert_eq!(got_w, want_w, "dot_w_panel cols={cols}");
    }
}

#[test]
fn threaded_correlation_sweep_matches_scalar_columns() {
    // p ragged against both PANEL_BLOCK and the 4-way thread chunking,
    // so chunk boundaries fall inside blocks.
    let (n, p) = test_shape((57, 1_001), (13, 101));
    let data = SyntheticSpec::new(n, p, 8).rho(0.3).seed(71).generate();
    let dense = dense_of(&data);
    let r = gaussian(n, 5);
    let want: Vec<f64> = (0..p).map(|j| blas::dot(dense.col(j), &r)).collect();
    for threads in THREADS {
        let engine = RuntimeEngine::native_threaded(threads);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let got = engine.correlation(&reg, &r).unwrap().expect("native kernel");
        assert_eq!(got, want, "threads={threads}: blocked sweep vs scalar dots");
    }
}

#[test]
fn threaded_gram_block_matches_scalar_weighted_dots() {
    // e = 7 rows over up to 4 workers: ragged row split; d = 5 is
    // ragged against PANEL_BLOCK.
    let (e, d, n) = (7usize, 5usize, 41usize);
    let xe_t: Vec<f64> = (0..e).flat_map(|a| gaussian(n, 200 + a as u64)).collect();
    let xd_t: Vec<f64> = (0..d).flat_map(|b| gaussian(n, 300 + b as u64)).collect();
    let w: Vec<f64> = (0..n).map(|i| 0.2 + 0.1 * ((i % 4) as f64)).collect();
    for threads in THREADS {
        let engine = RuntimeEngine::native_threaded(threads);
        let got_w = engine
            .gram_block(&xe_t, Some(&w), &xd_t, e, d, n)
            .unwrap()
            .expect("native kernel");
        let got_u = engine
            .gram_block(&xe_t, None, &xd_t, e, d, n)
            .unwrap()
            .expect("native kernel");
        for a in 0..e {
            let xa = &xe_t[a * n..(a + 1) * n];
            for b in 0..d {
                let xb = &xd_t[b * n..(b + 1) * n];
                assert_eq!(
                    got_w[a * d + b],
                    blas::dot_w(xa, xb, &w),
                    "threads={threads} weighted ({a},{b})"
                );
                assert_eq!(
                    got_u[a * d + b],
                    blas::dot(xb, xa),
                    "threads={threads} unweighted ({a},{b})"
                );
            }
        }
    }
}

/// The workspace arena's observable: after the warm-up steps the path
/// loop reuses its buffers, so later steps report zero workspace
/// growth, and the per-step kernel-time subsets stay consistent.
#[test]
fn path_workspace_reaches_allocation_free_steady_state() {
    let (n, p) = test_shape((60, 400), (16, 61));
    let data = SyntheticSpec::new(n, p, 6).rho(0.3).seed(91).generate();
    let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
    let fit = fitter.fit(&data.design, &data.response);
    assert!(fit.steps.len() > 5, "path long enough to settle");
    let growth: Vec<usize> = fit.steps.iter().map(|s| s.alloc_bytes).collect();
    // The arena grows while the active set grows, then stops. Exact
    // settle time depends on the screening trajectory, so the bar is
    // the property itself: allocation-free steps exist in the tail.
    assert!(
        growth.iter().skip(growth.len() / 2).any(|&b| b == 0),
        "no allocation-free steps in the second half of the path: {growth:?}"
    );
    for (k, s) in fit.steps.iter().enumerate() {
        // t_sweep/t_panel are nested timer reads inside the t_kkt /
        // t_hessian regions, so subsets hold up to clock granularity.
        assert!(s.t_sweep <= s.t_kkt + 1e-9, "step {k}: t_sweep > t_kkt");
        assert!(s.t_panel <= s.t_hessian + 1e-9, "step {k}: t_panel > t_hessian");
    }
}
