//! CSV ingestion for `hx pack`.
//!
//! Deliberately small: comma-separated numeric rows (one observation
//! per row), an optional non-numeric first row treated as a header,
//! and an optional response in the last column. Packing is the one
//! place a resident pass over external data is acceptable — the point
//! of `.hxd` is that everything *after* pack streams.

#![forbid(unsafe_code)]

use std::path::Path;

use crate::error::Result;
use crate::linalg::DenseMatrix;

/// Read `path` into a dense column-major design. With
/// `response_last`, the final column is split off and returned as the
/// response vector.
pub fn read_csv(path: &Path, response_last: bool) -> Result<(DenseMatrix, Option<Vec<f64>>)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {}: {e}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width: Option<usize> = None;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parsed: std::result::Result<Vec<f64>, _> =
            line.split(',').map(|f| f.trim().parse::<f64>()).collect();
        let vals = match parsed {
            Ok(vals) => vals,
            // A non-numeric first row is a header; anywhere else it is
            // a data error worth naming by line.
            Err(_) if i == 0 => continue,
            Err(e) => {
                return Err(crate::err!("line {} of {}: {e}", i + 1, path.display()));
            }
        };
        match width {
            None => width = Some(vals.len()),
            Some(w) if w != vals.len() => {
                return Err(crate::err!(
                    "line {} of {} has {} fields, expected {w}",
                    i + 1,
                    path.display(),
                    vals.len()
                ));
            }
            Some(_) => {}
        }
        rows.push(vals);
    }
    let n = rows.len();
    let cols = width.unwrap_or(0);
    if n == 0 || cols == 0 {
        return Err(crate::err!("{} holds no numeric data rows", path.display()));
    }
    let p = if response_last {
        if cols < 2 {
            return Err(crate::err!(
                "{} has {cols} column(s); splitting off a response needs at least 2",
                path.display()
            ));
        }
        cols - 1
    } else {
        cols
    };
    let mut col_major = vec![0.0; n * p];
    let mut response = if response_last { Some(Vec::with_capacity(n)) } else { None };
    for (i, row) in rows.iter().enumerate() {
        for (j, &v) in row[..p].iter().enumerate() {
            col_major[j * n + i] = v;
        }
        if let Some(y) = response.as_mut() {
            y.push(row[p]);
        }
    }
    Ok((DenseMatrix::from_col_major(n, p, col_major), response))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hxd-csv-{}-{tag}.csv", std::process::id()))
    }

    #[test]
    fn parses_header_rows_and_response_column() {
        let path = tmp("ok");
        std::fs::write(&path, "a,b,y\n1,2,3\n4,5,6\n\n7,8,9\n").expect("write");
        let (m, y) = read_csv(&path, true).expect("parse");
        assert_eq!((m.nrows(), m.ncols()), (3, 2));
        assert_eq!(m.col(0), &[1.0, 4.0, 7.0]);
        assert_eq!(m.col(1), &[2.0, 5.0, 8.0]);
        assert_eq!(y.expect("response"), vec![3.0, 6.0, 9.0]);

        let (m, y) = read_csv(&path, false).expect("parse without split");
        assert_eq!((m.nrows(), m.ncols()), (3, 3));
        assert!(y.is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn names_the_offending_line_on_errors() {
        let path = tmp("bad");
        std::fs::write(&path, "1,2\n3,nope\n").expect("write");
        let err = read_csv(&path, false).expect_err("bad float");
        assert!(err.to_string().contains("line 2"), "got: {err}");

        std::fs::write(&path, "1,2\n3,4,5\n").expect("write");
        let err = read_csv(&path, false).expect_err("ragged row");
        assert!(err.to_string().contains("has 3 fields, expected 2"), "got: {err}");

        std::fs::write(&path, "header,only\n").expect("write");
        let err = read_csv(&path, false).expect_err("no data");
        assert!(err.to_string().contains("no numeric data rows"), "got: {err}");

        std::fs::write(&path, "1\n2\n").expect("write");
        let err = read_csv(&path, true).expect_err("single column split");
        assert!(err.to_string().contains("at least 2"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }
}
