//! The project-invariant linter.
//!
//! Parses the crate's own sources with a light lexical pass — comment
//! and string-literal *contents* are masked to spaces (preserving line
//! structure), `#[cfg(test)]` item regions are tracked by brace
//! balance — and enforces the repo invariants as hard failures:
//!
//! * `safety-comment` — every `unsafe` token carries a `// SAFETY:`
//!   comment on the same line or within the three lines above it.
//! * `no-f32` — no `f32` token in `hessian/`, `screening/`, `solver/`,
//!   `runtime/shard.rs` or `storage/`: the screening math, the
//!   Gram/Hessian panels, and the on-disk `.hxd` column bytes are
//!   f64-exact by contract (`Backend::is_exact`; pack→read is
//!   bitwise), and a stray cast would corrupt the path silently.
//! * `no-unwrap` — no `.unwrap()` in library code outside tests and
//!   `cli.rs`/`main.rs`, unless the line (or the line above) carries
//!   an `// INVARIANT:` justification (the lock-poison policy).
//! * `no-raw-spawn` — no `std::thread::spawn` outside
//!   `runtime/shard.rs` and `coordinator/`: everything else uses
//!   scoped threads so no worker can outlive its data.
//! * `no-kernel-clock` — no `Instant::now()` in the per-column kernel
//!   files (`linalg/`, `runtime/native.rs`) or the `storage/` read
//!   path: timing belongs in the drivers (the shard pipeline times its
//!   own staging reads), never in inner loops or I/O decode loops.
//!
//! One rule is *advisory* — reported as a warning, never failing the
//! run:
//!
//! * `no-hot-alloc` — no `Vec::new()` / `vec![…]` / `.to_vec()` inside
//!   a `for`/`while`/`loop` body of the hot-path kernel files
//!   (`linalg/blas.rs`, `runtime/native.rs`): the hot path is
//!   allocation-free by design (workspace arenas + `_into` kernels),
//!   and an allocation sneaking back into an inner loop is the way
//!   that property rots. Advisory because loop-region detection is
//!   lexical, not a parse.
//!
//! Each rule has its own allowlist file under `xtask/lint/allow/`
//! (entries are `<path>` or `<path>:<line>` relative to `rust/src`;
//! `#` starts a comment). Unused entries are reported as warnings so
//! stale suppressions cannot accumulate. The lexer does not handle
//! raw string literals (`r"…"`, `r#"…"#`) — the crate does not use
//! them, and the `real-tree` unit test would flag the fallout if one
//! ever confused the masker.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories (trailing `/`) or exact files where `f32` is forbidden.
const F32_FORBIDDEN: &[&str] = &[
    "hessian/",
    "screening/",
    "solver/",
    "runtime/shard.rs",
    "storage/",
];
/// The only homes of raw `std::thread::spawn` (the upload pipeline and
/// the experiment pool); everything else must use `thread::scope`.
const SPAWN_ALLOWED: &[&str] = &["runtime/shard.rs", "coordinator/"];
/// Per-column kernel files and the storage read path: no wall-clock
/// reads in inner loops (the shard pipeline times staging externally).
const KERNEL_FILES: &[&str] = &["linalg/", "runtime/native.rs", "storage/"];
/// Binary/CLI surfaces where `.unwrap()` on user input is acceptable.
const UNWRAP_EXEMPT: &[&str] = &["cli.rs", "main.rs"];
/// Hot-path kernel files that must stay allocation-free inside loops
/// (the workspace-arena contract).
const HOT_ALLOC_FILES: &[&str] = &["linalg/blas.rs", "runtime/native.rs"];

/// How far above an `unsafe` token a `// SAFETY:` comment may sit.
const SAFETY_LOOKBACK: usize = 3;

pub const RULE_IDS: &[&str] = &[
    "safety-comment",
    "no-f32",
    "no-unwrap",
    "no-raw-spawn",
    "no-kernel-clock",
];

/// Warn-only rules: reported, allowlisted, but never part of the exit
/// status.
pub const ADVISORY_RULE_IDS: &[&str] = &["no-hot-alloc"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    /// Path relative to the scanned source root (unix separators).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub msg: String,
}

/// One source file, pre-lexed for the rules.
struct FileView {
    rel: String,
    raw_lines: Vec<String>,
    masked_lines: Vec<String>,
    in_test: Vec<bool>,
}

impl FileView {
    fn new(rel: &str, text: &str) -> Self {
        let masked = mask_comments_and_strings(text);
        let raw_lines: Vec<String> = text.lines().map(str::to_string).collect();
        let masked_lines: Vec<String> = masked.lines().map(str::to_string).collect();
        let in_test = test_regions(&masked_lines);
        Self {
            rel: rel.to_string(),
            raw_lines,
            masked_lines,
            in_test,
        }
    }

    fn violation(&self, rule: &'static str, idx: usize, msg: impl Into<String>) -> Violation {
        Violation {
            rule,
            path: self.rel.clone(),
            line: idx + 1,
            msg: msg.into(),
        }
    }
}

/// Replace comment bodies and string/char-literal contents with
/// spaces, preserving newlines (and therefore line numbers), so token
/// rules cannot be fooled by prose or literals. Comment markers are
/// erased along with their text; rules that *want* comments (SAFETY,
/// INVARIANT) read the raw lines instead.
fn mask_comments_and_strings(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = b.clone();
    let n = b.len();
    let mut i = 0;
    while i < n {
        match b[i] {
            '/' if i + 1 < n && b[i + 1] == '/' => {
                while i < n && b[i] != '\n' {
                    out[i] = ' ';
                    i += 1;
                }
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                let mut depth = 1usize;
                out[i] = ' ';
                out[i + 1] = ' ';
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        out[i] = ' ';
                        out[i + 1] = ' ';
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        out[i] = ' ';
                        out[i + 1] = ' ';
                        i += 2;
                    } else {
                        if b[i] != '\n' {
                            out[i] = ' ';
                        }
                        i += 1;
                    }
                }
            }
            '"' => {
                i += 1;
                while i < n {
                    if b[i] == '\\' {
                        out[i] = ' ';
                        if i + 1 < n && b[i + 1] != '\n' {
                            out[i + 1] = ' ';
                        }
                        i += 2;
                    } else if b[i] == '"' {
                        i += 1;
                        break;
                    } else {
                        if b[i] != '\n' {
                            out[i] = ' ';
                        }
                        i += 1;
                    }
                }
            }
            '\'' => {
                // Char literal ('x', '\n') vs. lifetime ('a): a
                // literal closes with a quote nearby; a lifetime never
                // does on the same token.
                if i + 2 < n && (b[i + 1] == '\\' || b[i + 2] == '\'') {
                    let mut j = i + 1;
                    while j < n && b[j] != '\'' {
                        if b[j] == '\\' && j + 1 < n {
                            out[j] = ' ';
                            out[j + 1] = ' ';
                            j += 2;
                        } else {
                            out[j] = ' ';
                            j += 1;
                        }
                    }
                    i = j + 1;
                } else {
                    i += 1;
                }
            }
            _ => i += 1,
        }
    }
    out.into_iter().collect()
}

/// Mark every line covered by a `#[cfg(test)]`-annotated item: from
/// the attribute line through the end of the following brace-balanced
/// block (computed on the masked text, so braces in strings/comments
/// do not skew the balance).
fn test_regions(masked_lines: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; masked_lines.len()];
    let mut i = 0;
    while i < masked_lines.len() {
        if !masked_lines[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut started = false;
        let mut j = i;
        while j < masked_lines.len() {
            for ch in masked_lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if started && depth <= 0 {
                break;
            }
            j += 1;
        }
        let end = j.min(masked_lines.len().saturating_sub(1));
        for flag in in_test.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
        i = end + 1;
    }
    in_test
}

/// Does this masked line open a `for`/`while`/`loop` body? `for` is
/// only a loop when it is neither an `impl … for …` header nor an
/// HRTB `for<'a>` binder.
fn is_loop_header(ml: &str) -> bool {
    if has_word(ml, "while") || has_word(ml, "loop") {
        return true;
    }
    if has_word(ml, "impl") {
        return false;
    }
    let bytes = ml.as_bytes();
    let mut start = 0;
    while let Some(pos) = ml[start..].find("for") {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_char(bytes[p - 1]);
        let after = p + 3;
        let after_ok = after >= bytes.len() || !is_ident_char(bytes[after]);
        let not_hrtb = after >= bytes.len() || bytes[after] != b'<';
        if before_ok && after_ok && not_hrtb {
            return true;
        }
        start = p + 1;
    }
    false
}

/// Mark every line inside a loop body (header line included): from
/// each loop header through the end of its brace-balanced block,
/// computed on the masked text. Every header is scanned
/// independently, so nested loops are covered by their outermost
/// region.
fn loop_regions(masked_lines: &[String]) -> Vec<bool> {
    let mut in_loop = vec![false; masked_lines.len()];
    for i in 0..masked_lines.len() {
        if !is_loop_header(&masked_lines[i]) {
            continue;
        }
        let mut depth: i64 = 0;
        let mut started = false;
        let mut j = i;
        while j < masked_lines.len() {
            for ch in masked_lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if started && depth <= 0 {
                break;
            }
            j += 1;
        }
        let end = j.min(masked_lines.len().saturating_sub(1));
        for flag in in_loop.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
    }
    in_loop
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Word-boundary token search (ASCII `word`, e.g. `unsafe`, `f32`).
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_char(bytes[p - 1]);
        let after = p + word.len();
        let after_ok = after >= bytes.len() || !is_ident_char(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

fn path_matches(rel: &str, patterns: &[&str]) -> bool {
    patterns
        .iter()
        .any(|p| if p.ends_with('/') { rel.starts_with(p) } else { rel == *p })
}

fn rule_safety(f: &FileView, out: &mut Vec<Violation>) {
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if !has_word(ml, "unsafe") {
            continue;
        }
        let lo = idx.saturating_sub(SAFETY_LOOKBACK);
        let covered = f.raw_lines[lo..=idx].iter().any(|l| l.contains("SAFETY:"));
        if !covered {
            out.push(f.violation(
                "safety-comment",
                idx,
                "`unsafe` without a `// SAFETY:` comment on the line or within 3 lines above",
            ));
        }
    }
}

fn rule_f32(f: &FileView, out: &mut Vec<Violation>) {
    if !path_matches(&f.rel, F32_FORBIDDEN) {
        return;
    }
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if has_word(ml, "f32") {
            out.push(f.violation(
                "no-f32",
                idx,
                "`f32` in an f64-exact module (is_exact contract: screening/Hessian math \
                 never runs in single precision)",
            ));
        }
    }
}

fn rule_unwrap(f: &FileView, out: &mut Vec<Violation>) {
    if UNWRAP_EXEMPT.iter().any(|e| f.rel == *e) {
        return;
    }
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if f.in_test[idx] || !ml.contains(".unwrap()") {
            continue;
        }
        let prev = if idx > 0 { f.raw_lines[idx - 1].as_str() } else { "" };
        if f.raw_lines[idx].contains("INVARIANT:") || prev.contains("INVARIANT:") {
            continue;
        }
        out.push(f.violation(
            "no-unwrap",
            idx,
            "`.unwrap()` in library code — use `expect` with an invariant message, propagate \
             via crate::error, or justify with an `// INVARIANT:` comment",
        ));
    }
}

fn rule_spawn(f: &FileView, out: &mut Vec<Violation>) {
    if path_matches(&f.rel, SPAWN_ALLOWED) {
        return;
    }
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if f.in_test[idx] {
            continue;
        }
        if ml.contains("thread::spawn") {
            out.push(f.violation(
                "no-raw-spawn",
                idx,
                "raw `thread::spawn` outside runtime/shard.rs and coordinator/ — use \
                 `std::thread::scope` so workers cannot outlive their data",
            ));
        }
    }
}

fn rule_kernel_clock(f: &FileView, out: &mut Vec<Violation>) {
    if !path_matches(&f.rel, KERNEL_FILES) {
        return;
    }
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if f.in_test[idx] {
            continue;
        }
        if ml.contains("Instant::now") {
            out.push(f.violation(
                "no-kernel-clock",
                idx,
                "`Instant::now()` in a per-column kernel file — time in the drivers \
                 (path/, runtime/shard.rs), never inside inner loops",
            ));
        }
    }
}

/// Advisory: the hot-path kernels are allocation-free inside loops by
/// design — allocations are hoisted into workspace arenas or taken as
/// `_into` out-params. Lexical loop detection, hence warn-only.
fn rule_hot_alloc(f: &FileView, out: &mut Vec<Violation>) {
    if !path_matches(&f.rel, HOT_ALLOC_FILES) {
        return;
    }
    let in_loop = loop_regions(&f.masked_lines);
    for (idx, ml) in f.masked_lines.iter().enumerate() {
        if f.in_test[idx] || !in_loop[idx] {
            continue;
        }
        if ml.contains("Vec::new()") || ml.contains("vec!") || ml.contains(".to_vec()") {
            out.push(f.violation(
                "no-hot-alloc",
                idx,
                "heap allocation inside a kernel inner loop — hoist into a workspace \
                 buffer (SweepScratch/SolverScratch/Workspace) or take an `_into` out-param",
            ));
        }
    }
}

/// Run every rule over `(relative_path, contents)` pairs. Pure — this
/// is the seam the unit tests drive with fixture snippets.
fn check_files(files: &[(String, String)]) -> Vec<Violation> {
    let mut out = Vec::new();
    for (rel, text) in files {
        let f = FileView::new(rel, text);
        rule_safety(&f, &mut out);
        rule_f32(&f, &mut out);
        rule_unwrap(&f, &mut out);
        rule_spawn(&f, &mut out);
        rule_kernel_clock(&f, &mut out);
        rule_hot_alloc(&f, &mut out);
    }
    out
}

/// One rule's allowlist: entries are `<path>` (whole file) or
/// `<path>:<line>`, relative to the source root.
struct Allowlist {
    entries: Vec<(String, Option<usize>)>,
    used: Vec<bool>,
}

impl Allowlist {
    fn parse(text: &str) -> Self {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            match line.rsplit_once(':') {
                Some((path, ln)) if ln.chars().all(|c| c.is_ascii_digit()) && !ln.is_empty() => {
                    entries.push((path.to_string(), ln.parse().ok()));
                }
                _ => entries.push((line.to_string(), None)),
            }
        }
        let used = vec![false; entries.len()];
        Self { entries, used }
    }

    fn permits(&mut self, v: &Violation) -> bool {
        let mut hit = false;
        for (i, (path, line)) in self.entries.iter().enumerate() {
            let line_ok = match line {
                Some(l) => *l == v.line,
                None => true,
            };
            if *path == v.path && line_ok {
                self.used[i] = true;
                hit = true;
            }
        }
        hit
    }

    fn unused(&self) -> Vec<String> {
        self.entries
            .iter()
            .zip(&self.used)
            .filter(|(_, &u)| !u)
            .map(|((p, l), _)| match l {
                Some(l) => format!("{p}:{l}"),
                None => p.clone(),
            })
            .collect()
    }
}

fn allow_file_name(rule: &str) -> String {
    format!("{rule}.allow")
}

fn collect_rs_files(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut stack = vec![root.to_path_buf()];
    let mut files = Vec::new();
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .to_string_lossy()
                    .into_owned();
                let text = std::fs::read_to_string(&path)?;
                files.push((rel, text));
            }
        }
    }
    files.sort();
    Ok(files)
}

fn workspace_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is <workspace>/xtask at compile time; the
    // parent is the workspace root regardless of the invocation cwd.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

pub fn run(args: &[String]) -> ExitCode {
    let root = workspace_root();
    let mut src_root = root.join("rust").join("src");
    let mut allow_dir = root.join("xtask").join("lint").join("allow");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => src_root = PathBuf::from(v),
                None => {
                    eprintln!("lint: --root needs a value");
                    return ExitCode::from(2);
                }
            },
            "--allow-dir" => match it.next() {
                Some(v) => allow_dir = PathBuf::from(v),
                None => {
                    eprintln!("lint: --allow-dir needs a value");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("lint: unknown flag `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let files = match collect_rs_files(&src_root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: cannot read {}: {e}", src_root.display());
            return ExitCode::FAILURE;
        }
    };
    let violations = check_files(&files);

    let mut allow: Vec<(&str, Allowlist)> = Vec::new();
    for rule in RULE_IDS.iter().chain(ADVISORY_RULE_IDS) {
        let path = allow_dir.join(allow_file_name(rule));
        let text = std::fs::read_to_string(&path).unwrap_or_default();
        allow.push((rule, Allowlist::parse(&text)));
    }

    let mut reported = 0usize;
    let mut advisories = 0usize;
    for v in &violations {
        let permitted = allow
            .iter_mut()
            .find(|(rule, _)| *rule == v.rule)
            .is_some_and(|(_, list)| list.permits(v));
        if permitted {
            continue;
        }
        if ADVISORY_RULE_IDS.contains(&v.rule) {
            // Advisory: visible, allowlistable, never the exit status.
            println!("warning[{}] rust/src/{}:{}: {}", v.rule, v.path, v.line, v.msg);
            advisories += 1;
        } else {
            println!("error[{}] rust/src/{}:{}: {}", v.rule, v.path, v.line, v.msg);
            reported += 1;
        }
    }
    for (rule, list) in &allow {
        for entry in list.unused() {
            println!("warning[{rule}] unused allowlist entry: {entry}");
        }
    }
    println!(
        "lint: {} files scanned, {} rules ({} advisory), {} violation(s), {} advisory warning(s)",
        files.len(),
        RULE_IDS.len() + ADVISORY_RULE_IDS.len(),
        ADVISORY_RULE_IDS.len(),
        reported,
        advisories
    );
    if reported > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_one(rel: &str, text: &str) -> Vec<Violation> {
        check_files(&[(rel.to_string(), text.to_string())])
    }

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn masking_hides_comments_and_strings_keeps_lines() {
        let src = "let a = \"unsafe f32\"; // unsafe f32\nlet b = 1;\n";
        let m = mask_comments_and_strings(src);
        assert_eq!(m.lines().count(), src.lines().count());
        assert!(!m.contains("unsafe"));
        assert!(!m.contains("f32"));
        assert!(m.contains("let b = 1;"));
    }

    #[test]
    fn masking_handles_block_comments_escapes_and_char_literals() {
        let src = "/* f32\n unsafe */ let c = '\\''; let d = 'x'; let l: &'static str = \"\\\"f32\";\n";
        let m = mask_comments_and_strings(src);
        assert!(!m.contains("f32"));
        assert!(!m.contains("unsafe"));
        assert!(m.contains("let d ="));
        assert!(m.contains("&'static str"));
    }

    #[test]
    fn test_region_tracking_covers_balanced_blocks() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {\n    }\n}\nfn c() {}\n";
        let f = FileView::new("x.rs", src);
        assert_eq!(
            f.in_test,
            vec![false, true, true, true, true, true, false]
        );
    }

    #[test]
    fn safety_rule_flags_seeded_violation_and_accepts_comment() {
        let bad = "fn f(x: &[f64]) -> f64 {\n    unsafe { *x.get_unchecked(0) }\n}\n";
        let vs = check_one("linalg/blas.rs", bad);
        assert_eq!(rules_of(&vs), vec!["safety-comment"]);
        assert_eq!(vs[0].line, 2);

        let good = "fn f(x: &[f64]) -> f64 {\n    // SAFETY: caller guarantees x is non-empty.\n    unsafe { *x.get_unchecked(0) }\n}\n";
        assert!(check_one("linalg/blas.rs", good).is_empty());
    }

    #[test]
    fn safety_comment_must_be_within_lookback_window() {
        let far = "// SAFETY: too far away.\nfn f(x: &[f64]) -> f64 {\n    let n = x.len();\n    let _ = n;\n    unsafe { *x.get_unchecked(0) }\n}\n";
        let vs = check_one("linalg/blas.rs", far);
        assert_eq!(rules_of(&vs), vec!["safety-comment"]);
    }

    #[test]
    fn f32_rule_is_scoped_to_the_exact_f64_modules() {
        let bad = "pub fn g(v: f32) -> f32 { v }\n";
        assert_eq!(rules_of(&check_one("hessian/mod.rs", bad)), vec!["no-f32"]);
        assert_eq!(rules_of(&check_one("screening/mod.rs", bad)), vec!["no-f32"]);
        assert_eq!(rules_of(&check_one("runtime/shard.rs", bad)), vec!["no-f32"]);
        // .hxd bytes are f64-exact: a cast anywhere in storage/ would
        // silently break the pack→read bitwise contract.
        assert_eq!(rules_of(&check_one("storage/hxd.rs", bad)), vec!["no-f32"]);
        // pjrt may buffer-convert; the rule does not apply there.
        assert!(check_one("runtime/pjrt.rs", bad).is_empty());
        // prose about f32 in a comment is not a token.
        let doc = "//! Never build H from f32 values.\npub fn ok() {}\n";
        assert!(check_one("hessian/mod.rs", doc).is_empty());
    }

    #[test]
    fn unwrap_rule_honors_tests_exemptions_and_invariant_comments() {
        let bad = "pub fn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n";
        let vs = check_one("solver/mod.rs", bad);
        assert_eq!(rules_of(&vs), vec!["no-unwrap"]);
        assert_eq!(vs[0].line, 2);

        let invariant = "pub fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    // INVARIANT: lock poisoning aborts via the joined worker.\n    *m.lock().unwrap()\n}\n";
        assert!(check_one("solver/mod.rs", invariant).is_empty());

        let in_test = "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n";
        assert!(check_one("solver/mod.rs", in_test).is_empty());

        assert!(check_one("cli.rs", bad).is_empty());
        assert!(check_one("main.rs", bad).is_empty());
    }

    #[test]
    fn spawn_rule_allows_only_the_pipeline_and_the_coordinator() {
        let bad = "pub fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(rules_of(&check_one("path/mod.rs", bad)), vec!["no-raw-spawn"]);
        assert!(check_one("runtime/shard.rs", bad).is_empty());
        assert!(check_one("coordinator/mod.rs", bad).is_empty());
        // Scoped spawns are fine everywhere.
        let scoped = "pub fn f() {\n    std::thread::scope(|s| {\n        s.spawn(|| {});\n    });\n}\n";
        assert!(check_one("path/mod.rs", scoped).is_empty());
    }

    #[test]
    fn kernel_clock_rule_is_scoped_to_kernel_files() {
        let bad = "pub fn f() {\n    let _t = std::time::Instant::now();\n}\n";
        assert_eq!(
            rules_of(&check_one("linalg/blas.rs", bad)),
            vec!["no-kernel-clock"]
        );
        assert_eq!(
            rules_of(&check_one("runtime/native.rs", bad)),
            vec!["no-kernel-clock"]
        );
        assert_eq!(
            rules_of(&check_one("storage/hxd.rs", bad)),
            vec!["no-kernel-clock"]
        );
        // Drivers may time freely.
        assert!(check_one("path/mod.rs", bad).is_empty());
        assert!(check_one("runtime/shard.rs", bad).is_empty());
    }

    #[test]
    fn hot_alloc_rule_flags_loop_allocations_in_kernel_files() {
        let bad = "pub fn f(n: usize) {\n    for j in 0..n {\n        let tmp = vec![0.0; j];\n        std::hint::black_box(&tmp);\n    }\n}\n";
        assert_eq!(rules_of(&check_one("linalg/blas.rs", bad)), vec!["no-hot-alloc"]);
        assert_eq!(
            rules_of(&check_one("runtime/native.rs", bad)),
            vec!["no-hot-alloc"]
        );
        assert_eq!(check_one("linalg/blas.rs", bad)[0].line, 3);
        // Drivers may allocate freely — the rule is kernel-file scoped.
        assert!(check_one("path/mod.rs", bad).is_empty());

        let while_clone = "pub fn f(x: &[f64]) {\n    let mut i = 0;\n    while i < x.len() {\n        let _c = x.to_vec();\n        i += 1;\n    }\n}\n";
        assert_eq!(
            rules_of(&check_one("runtime/native.rs", while_clone)),
            vec!["no-hot-alloc"]
        );
    }

    #[test]
    fn hot_alloc_rule_ignores_hoisted_impl_headers_and_tests() {
        // Allocation *before* the loop is the workspace pattern.
        let hoisted = "pub fn f(n: usize) {\n    let mut tmp = Vec::new();\n    for j in 0..n {\n        tmp.push(j);\n    }\n}\n";
        assert!(check_one("linalg/blas.rs", hoisted).is_empty());
        // `impl Trait for Type` is not a loop header.
        let imp = "pub struct S;\nimpl Clone for S {\n    fn clone(&self) -> S {\n        let _v: Vec<f64> = Vec::new();\n        S\n    }\n}\n";
        assert!(check_one("runtime/native.rs", imp).is_empty());
        // Test code is exempt, like every other rule.
        let in_test = "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        for _ in 0..3 {\n            let _v = vec![1];\n        }\n    }\n}\n";
        assert!(check_one("linalg/blas.rs", in_test).is_empty());
    }

    #[test]
    fn allowlist_permits_by_file_and_by_line_and_tracks_usage() {
        let v = Violation {
            rule: "no-unwrap",
            path: "solver/mod.rs".to_string(),
            line: 7,
            msg: String::new(),
        };
        let mut by_file = Allowlist::parse("# comment\nsolver/mod.rs\n");
        assert!(by_file.permits(&v));
        assert!(by_file.unused().is_empty());

        let mut by_line = Allowlist::parse("solver/mod.rs:7\n");
        assert!(by_line.permits(&v));

        let mut wrong_line = Allowlist::parse("solver/mod.rs:8\n");
        assert!(!wrong_line.permits(&v));
        assert_eq!(wrong_line.unused(), vec!["solver/mod.rs:8".to_string()]);
    }

    #[test]
    fn real_tree_is_lint_clean() {
        // The linter's strongest test: the actual crate sources must
        // pass every rule, and the SAFETY/f32 allowlists must be
        // EMPTY (repo acceptance bar — suppressions are allowed for
        // no-unwrap only).
        let root = workspace_root();
        let files = collect_rs_files(&root.join("rust").join("src")).expect("rust/src readable");
        assert!(files.len() > 20, "expected the full source tree");
        let violations = check_files(&files);

        let allow_dir = root.join("xtask").join("lint").join("allow");
        let mut remaining = Vec::new();
        for v in &violations {
            // Advisory rules warn without failing the run; holding the
            // real tree to them here would silently promote them to
            // blocking.
            if ADVISORY_RULE_IDS.contains(&v.rule) {
                continue;
            }
            let text =
                std::fs::read_to_string(allow_dir.join(allow_file_name(v.rule))).unwrap_or_default();
            if !Allowlist::parse(&text).permits(v) {
                remaining.push(v.clone());
            }
        }
        assert!(remaining.is_empty(), "lint violations: {remaining:?}");

        for rule in ["safety-comment", "no-f32"] {
            let text =
                std::fs::read_to_string(allow_dir.join(allow_file_name(rule))).unwrap_or_default();
            assert!(
                Allowlist::parse(&text).entries.is_empty(),
                "{rule} allowlist must stay empty"
            );
        }
    }
}
