//! CLI argument parsing substrate (no `clap` offline): positional
//! arguments, `--key value` options and `--flag` switches, with typed
//! accessors and friendly error messages.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (program name excluded).
    /// `--key value` forms an option unless the token after `--key` is
    /// itself `--something`, in which case `--key` is a flag.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = args.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{key}: expected a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{key}: expected an integer, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("exp fig3 --reps 5 --out results --verbose");
        assert_eq!(a.pos(0), Some("exp"));
        assert_eq!(a.pos(1), Some("fig3"));
        assert_eq!(a.get("reps"), Some("5"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--eps=1e-6 --method=hessian");
        assert_eq!(a.get_f64("eps").unwrap(), Some(1e-6));
        assert_eq!(a.get("method"), Some("hessian"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --n 100");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_usize("n").unwrap(), Some(100));
    }

    #[test]
    fn typed_errors() {
        let a = parse("--n abc");
        assert!(a.get_usize("n").is_err());
        assert!(a.get_f64("n").is_err());
        assert_eq!(a.get_usize("missing").unwrap(), None);
    }

    #[test]
    fn cv_flag_surface_parses() {
        // The `hx cv` option surface (main.rs cmd_cv / cmd_cv_hxd);
        // `make check-cv` smokes the same vector through the binary.
        let a = parse(
            "cv --n 120 --p 300 --folds 4 --threads 8 --engine-threads 2 \
             --folds-seed 7 --shards 3 --profile",
        );
        assert_eq!(a.pos(0), Some("cv"));
        assert_eq!(a.get_usize("folds").unwrap(), Some(4));
        assert_eq!(a.get_usize("threads").unwrap(), Some(8));
        assert_eq!(a.get_usize("engine-threads").unwrap(), Some(2));
        assert_eq!(a.get_usize("folds-seed").unwrap(), Some(7));
        assert_eq!(a.get_usize("shards").unwrap(), Some(3));
        assert!(a.flag("profile"));
    }

    #[test]
    fn list_option() {
        let a = parse("--methods hessian,working,celer,");
        assert_eq!(
            a.get_list("methods").unwrap(),
            vec!["hessian", "working", "celer"]
        );
    }
}
