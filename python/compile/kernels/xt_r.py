"""Layer-1 Pallas kernel: the correlation sweep c = Xᵀr.

This is the hot spot of every screening method in the paper: the KKT
checks, the strong rule, Gap-Safe screening and the Hessian rule's
restricted inner products are all dominated by Xᵀ·(residual) over the
candidate set (§3.3.4: the per-step O(np) cost). The kernel computes it
as a tiled matvec over the *transposed* design (p, n) — the layout that
matches the rust coordinator's column-major storage byte-for-byte.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks panels of
`TP` predictors (rows of Xᵀ); within a panel, a second grid axis walks
`TN`-wide slices of the sample dimension, accumulating partial products
in the output block, which Pallas keeps resident in VMEM across the
inner axis. Per grid step the VMEM working set is

    TP·TN·4  (X panel)  +  TN·4 (r slice)  +  TP·4 (accumulator)

— 256 KiB for the default TP=256, TN=256 in f32, far under the ~16 MiB
VMEM budget, leaving room for double-buffering the HBM→VMEM streams.
The panel product is a (TP, TN) × (TN, 1) dot, which the MXU executes
natively with f32 accumulation.

The kernel is lowered with ``interpret=True`` everywhere in this repo:
the CPU PJRT plugin cannot execute Mosaic custom-calls, so interpret
mode is both the correctness path and what the AOT artifacts embed
(pallas interpret lowers to plain HLO). Structure, not interpreted
wall-clock, is the optimization target — see EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xt_r_kernel(xt_ref, r_ref, o_ref):
    """One grid step: o[ip] (+)= XT[ip, in] @ r[in]."""
    i_n = pl.program_id(1)

    @pl.when(i_n == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TP, TN) @ (TN, 1) -> (TP, 1); f32 accumulate on the MXU.
    o_ref[...] += jnp.dot(
        xt_ref[...], r_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_tile(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (grids must tile
    evenly; callers pad when they want power-of-two tiles)."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tp", "tn"))
def xt_r(xt: jnp.ndarray, r: jnp.ndarray, tp: int = 256, tn: int = 256) -> jnp.ndarray:
    """c = Xᵀ r via the Pallas kernel.

    ``xt``: (p, n) transposed design; ``r``: (n, 1). Returns (p, 1).
    ``tp``/``tn`` are tile-size *targets*; actual tiles are the largest
    divisors of p and n not exceeding them.
    """
    p, n = xt.shape
    assert r.shape == (n, 1), f"r must be (n,1), got {r.shape}"
    tp = _pick_tile(p, tp)
    tn = _pick_tile(n, tn)
    grid = (p // tp, n // tn)
    return pl.pallas_call(
        _xt_r_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, tn), lambda ip, i_n: (ip, i_n)),
            pl.BlockSpec((tn, 1), lambda ip, i_n: (i_n, 0)),
        ],
        out_specs=pl.BlockSpec((tp, 1), lambda ip, i_n: (ip, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), xt.dtype),
        interpret=True,
    )(xt, r)


def vmem_bytes(tp: int, tn: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM working set estimate (see module docstring);
    used by the L1 perf notes in EXPERIMENTS.md."""
    return dtype_bytes * (tp * tn + tn + tp)
