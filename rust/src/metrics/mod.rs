//! Measurement substrate for the benchmark harness: repeated-run
//! timing, mean / 95% confidence intervals (the error bars in the
//! paper's Figures 3, 6, 10, 11), and plain-text table/CSV rendering
//! of the experiment outputs.

use std::time::Instant;

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    /// Ordinary 95% CI half-width: 1.96·sd/√n (the paper's "standard
    /// 95% confidence intervals").
    pub ci_half: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: f64::NAN,
                sd: f64::NAN,
                ci_half: f64::NAN,
            };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let sd = var.sqrt();
        Summary {
            n,
            mean,
            sd,
            ci_half: 1.96 * sd / (n as f64).sqrt(),
        }
    }

    /// Summarize a projection of a record slice — e.g. per-fold wall
    /// times out of CV profile records:
    /// `Summary::over(&stats.folds, |f| f.wall_seconds)`.
    pub fn over<T>(items: &[T], f: impl Fn(&T) -> f64) -> Summary {
        let vals: Vec<f64> = items.iter().map(&f).collect();
        Summary::of(&vals)
    }

    pub fn lo(&self) -> f64 {
        self.mean - self.ci_half
    }

    pub fn hi(&self) -> f64 {
        self.mean + self.ci_half
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

/// Round to `sig` significant figures (paper tables use 3–4).
pub fn sig_figs(x: f64, sig: u32) -> f64 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let mag = x.abs().log10().floor();
    let factor = 10f64.powf(sig as f64 - 1.0 - mag);
    (x * factor).round() / factor
}

/// A simple left-aligned text table (markdown-flavoured) for CLI output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV rendering for machine consumption (results/ directory).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with 3 significant figures (the paper's convention).
pub fn fmt_secs(s: f64) -> String {
    format!("{}", sig_figs(s, 3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // sample sd of 1..4 = sqrt(5/3)
        assert!((s.sd - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((s.ci_half - 1.96 * s.sd / 2.0).abs() < 1e-12);
        assert!(s.lo() < s.mean && s.mean < s.hi());
    }

    #[test]
    fn summary_degenerate() {
        assert!(Summary::of(&[]).mean.is_nan());
        let one = Summary::of(&[5.0]);
        assert_eq!(one.mean, 5.0);
        assert_eq!(one.sd, 0.0);
        assert_eq!(one.ci_half, 0.0);
    }

    #[test]
    fn summary_over_projects_records() {
        struct Rec {
            w: f64,
        }
        let recs = [Rec { w: 1.0 }, Rec { w: 2.0 }, Rec { w: 3.0 }];
        let s = Summary::over(&recs, |r| r.w);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(Summary::over::<Rec>(&[], |r| r.w).n, 0);
    }

    #[test]
    fn sig_figs_rounding() {
        assert_eq!(sig_figs(123.456, 3), 123.0);
        assert_eq!(sig_figs(0.0012345, 3), 0.00123);
        assert_eq!(sig_figs(78.84, 3), 78.8);
        assert_eq!(sig_figs(0.0, 3), 0.0);
        assert_eq!(sig_figs(-123.456, 2), -120.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(&["method", "time"]);
        t.row(vec!["hessian".into(), "1.0".into()]);
        t.row(vec!["working".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("| method"));
        assert!(r.lines().count() == 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "method,time");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
