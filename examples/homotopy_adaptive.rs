//! Approximate homotopy (§3.3.6): adaptive λ-grid placement using the
//! Hessian tracker's closed-form path derivative. Compares the
//! breakpoint-driven grid against the default log-spaced grid on a
//! design where the active set churns unevenly.
//!
//!     cargo run --release --example homotopy_adaptive

use hessian_screening::metrics::Table;
use hessian_screening::path::{fit_approximate_homotopy, HomotopySettings};
use hessian_screening::prelude::*;

fn main() {
    let data = SyntheticSpec::new(300, 1_000, 15)
        .rho(0.5)
        .snr(3.0)
        .seed(99)
        .generate();

    // Fixed log grid (the glmnet default the paper uses).
    let fixed = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
        .fit(&data.design, &data.response);

    // Adaptive grid.
    let hom = fit_approximate_homotopy(&data.design, &data.response, &HomotopySettings::default());

    println!(
        "fixed grid: {} steps, {} passes, {:.3}s",
        fixed.lambdas.len(),
        fixed.total_passes(),
        fixed.total_time
    );
    println!(
        "adaptive  : {} steps, {} passes, {:.3}s\n",
        hom.lambdas.len(),
        hom.total_passes(),
        hom.total_time
    );

    // Where did the adaptive grid place its knots? Show the support
    // size trajectory: steps cluster where the active set changes.
    let mut table = Table::new(&["step", "lambda", "active", "Δlambda/lambda"]);
    for k in 1..hom.lambdas.len().min(25) {
        table.row(vec![
            format!("{k}"),
            format!("{:.5}", hom.lambdas[k]),
            format!("{}", hom.steps[k].active),
            format!("{:.4}", 1.0 - hom.lambdas[k] / hom.lambdas[k - 1]),
        ]);
    }
    println!("{}", table.render());

    // The adaptive path must trace the same solutions: refit the
    // standard driver on the homotopy's own grid and compare exactly.
    let p = data.design_p();
    let mut settings = hessian_screening::path::PathSettings::default();
    settings.lambda_path = Some(hom.lambdas.clone());
    settings.cd.eps = 1e-6;
    let refit = PathFitter::new(Loss::Gaussian, ScreeningKind::Working)
        .with_settings(settings)
        .fit(&data.design, &data.response);
    let m = hom.lambdas.len().min(refit.lambdas.len());
    let mut worst = 0.0f64;
    for k in 0..m {
        let a = hom.beta_dense(k, p);
        let b = refit.beta_dense(k, p);
        for j in 0..p {
            worst = worst.max((a[j] - b[j]).abs());
        }
    }
    println!("verified against a same-grid refit over {m} steps: max |Δβ| = {worst:.2e}");
    assert!(worst < 0.05, "homotopy and refit disagree ({worst})");
}

trait DesignP {
    fn design_p(&self) -> usize;
}

impl DesignP for Dataset {
    fn design_p(&self) -> usize {
        self.p()
    }
}
