//! Correlated-Gaussian design sampling.
//!
//! The paper's simulated experiments (§4.1) draw rows of X i.i.d. from
//! N(0, Σ) with Σ either compound-symmetric (pairwise correlation ρ) or
//! block/AR-structured (used by the simulated real-data analogues). For
//! compound symmetry we exploit the one-factor representation
//!
//! ```text
//! x_j = sqrt(ρ) · z0 + sqrt(1 − ρ) · z_j ,  z ~ N(0, I)
//! ```
//!
//! which is O(np) instead of the O(p²) Cholesky route and exactly
//! matches Σ = ρ 11ᵀ + (1−ρ) I.

use super::Xoshiro256pp;

/// Source of correlated Gaussian design rows.
pub struct GaussianSource<'a> {
    rng: &'a mut Xoshiro256pp,
}

impl<'a> GaussianSource<'a> {
    pub fn new(rng: &'a mut Xoshiro256pp) -> Self {
        Self { rng }
    }

    /// Fill `row` (length p) with one draw from N(0, Σ_ρ) where
    /// Σ_ρ = ρ 11ᵀ + (1−ρ) I (compound symmetry / equicorrelation).
    pub fn fill_equicorrelated_row(&mut self, row: &mut [f64], rho: f64) {
        debug_assert!((0.0..1.0).contains(&rho));
        let shared = rho.sqrt() * self.rng.next_gaussian();
        let own = (1.0 - rho).sqrt();
        for v in row.iter_mut() {
            *v = shared + own * self.rng.next_gaussian();
        }
    }

    /// Fill `row` with one draw from an AR(1) process with parameter
    /// `rho`: corr(x_i, x_j) = ρ^|i−j|. Used by some dataset analogues
    /// to mimic locally-correlated (e.g. genomic) designs.
    pub fn fill_ar1_row(&mut self, row: &mut [f64], rho: f64) {
        debug_assert!((-1.0..1.0).contains(&rho));
        if row.is_empty() {
            return;
        }
        let innov = (1.0 - rho * rho).sqrt();
        row[0] = self.rng.next_gaussian();
        for j in 1..row.len() {
            row[j] = rho * row[j - 1] + innov * self.rng.next_gaussian();
        }
    }

    /// Fill `row` with a block-equicorrelated draw: predictors are split
    /// into contiguous blocks of size `block`, correlation `rho` within a
    /// block and 0 across blocks. Mimics gene-module structure.
    pub fn fill_block_row(&mut self, row: &mut [f64], rho: f64, block: usize) {
        debug_assert!(block > 0);
        let own = (1.0 - rho).sqrt();
        let mut j = 0;
        while j < row.len() {
            let shared = rho.sqrt() * self.rng.next_gaussian();
            let end = (j + block).min(row.len());
            for v in &mut row[j..end] {
                *v = shared + own * self.rng.next_gaussian();
            }
            j = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_corr(
        fill: impl Fn(&mut GaussianSource, &mut [f64]),
        p: usize,
        n: usize,
        a: usize,
        b: usize,
    ) -> f64 {
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let mut row = vec![0.0; p];
        let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let mut src = GaussianSource::new(&mut rng);
            fill(&mut src, &mut row);
            let (x, y) = (row[a], row[b]);
            sa += x;
            sb += y;
            saa += x * x;
            sbb += y * y;
            sab += x * y;
        }
        let nf = n as f64;
        let cov = sab / nf - (sa / nf) * (sb / nf);
        let va = saa / nf - (sa / nf) * (sa / nf);
        let vb = sbb / nf - (sb / nf) * (sb / nf);
        cov / (va * vb).sqrt()
    }

    #[test]
    fn equicorrelated_pairwise_correlation() {
        for &rho in &[0.0, 0.4, 0.8] {
            let c = sample_corr(
                |s, r| s.fill_equicorrelated_row(r, rho),
                10,
                40_000,
                1,
                7,
            );
            assert!((c - rho).abs() < 0.02, "rho={rho} got {c}");
        }
    }

    #[test]
    fn ar1_decay() {
        let c1 = sample_corr(|s, r| s.fill_ar1_row(r, 0.7), 10, 40_000, 3, 4);
        let c3 = sample_corr(|s, r| s.fill_ar1_row(r, 0.7), 10, 40_000, 3, 6);
        assert!((c1 - 0.7).abs() < 0.02, "lag1 {c1}");
        assert!((c3 - 0.7f64.powi(3)).abs() < 0.03, "lag3 {c3}");
    }

    #[test]
    fn block_structure_within_vs_across() {
        let within = sample_corr(|s, r| s.fill_block_row(r, 0.6, 5), 10, 40_000, 1, 3);
        let across = sample_corr(|s, r| s.fill_block_row(r, 0.6, 5), 10, 40_000, 3, 7);
        assert!((within - 0.6).abs() < 0.02, "within {within}");
        assert!(across.abs() < 0.02, "across {across}");
    }

    #[test]
    fn unit_marginal_variance() {
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let mut row = vec![0.0; 4];
        let n = 60_000;
        let mut s = 0.0;
        let mut ss = 0.0;
        for _ in 0..n {
            GaussianSource::new(&mut rng).fill_equicorrelated_row(&mut row, 0.5);
            s += row[2];
            ss += row[2] * row[2];
        }
        let var = ss / n as f64 - (s / n as f64).powi(2);
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }
}
