//! Approximate homotopy (§3.3.6, extension).
//!
//! Because the Hessian tracker gives dβ/dλ = −H⁻¹·sign(β̂_A) in closed
//! form (Theorem 3.1), the *next* λ can be chosen adaptively instead of
//! on a fixed log grid: within the linearity region the solution is
//! exact, so we jump directly to (just past) the next predicted
//! *breakpoint* — the λ where a predictor enters (|ĉ_j(λ)| reaches λ)
//! or leaves (β̂_j(λ) crosses 0) — clipped to a maximum multiplicative
//! step. This distributes the grid the way Mairal & Yu's complexity
//! analysis suggests: dense where the active set churns, sparse where
//! nothing happens.
//!
//! Implemented for the ordinary lasso (the setting of Theorem 3.1).

use super::{PathFit, PathSettings, StepStats};
use crate::hessian::HessianTracker;
use crate::linalg::Design;
use crate::loss::Loss;
use crate::rng::Xoshiro256pp;
use crate::screening::ScreeningKind;
use crate::solver::{solve_subproblem, SolveState};

#[derive(Clone, Debug)]
pub struct HomotopySettings {
    /// Stop at λ_min = ratio·λ_max.
    pub lambda_min_ratio: f64,
    /// Never step below `min_step`·λ_k in one jump (grid-density cap).
    pub min_step: f64,
    /// Safety margin past the predicted breakpoint (fraction of λ).
    pub overshoot: f64,
    /// Hard cap on the number of steps.
    pub max_steps: usize,
    pub base: PathSettings,
}

impl Default for HomotopySettings {
    fn default() -> Self {
        Self {
            lambda_min_ratio: 1e-2,
            min_step: 0.5,
            overshoot: 1e-3,
            max_steps: 500,
            base: PathSettings::default(),
        }
    }
}

/// Fit an adaptively-gridded lasso path. Returns a [`PathFit`] whose
/// `lambdas` are the chosen breakpoint-driven grid.
pub fn fit_approximate_homotopy<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    settings: &HomotopySettings,
) -> PathFit {
    let t_total = std::time::Instant::now();
    let loss = Loss::Gaussian;
    let n = design.nrows();
    let p = design.ncols();
    let col_sq_norms: Vec<f64> = (0..p).map(|j| design.col_sq_norm(j)).collect();
    let zeta = loss.zeta(y);
    let null_dev = loss.null_deviance(y);

    let mut state = SolveState::new(n, p);
    state.refresh(design, y, loss);
    let mut c: Vec<f64> = (0..p).map(|j| design.col_dot(j, &state.resid)).collect();
    let lambda_max = c.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let lambda_min = settings.lambda_min_ratio * lambda_max;

    let mut tracker = HessianTracker::new(n as f64 * 1e-4);
    let mut rng = Xoshiro256pp::seed_from_u64(settings.base.seed);
    let mut fit = PathFit {
        lambdas: vec![lambda_max],
        betas: vec![Vec::new()],
        dev_ratios: vec![0.0],
        steps: vec![StepStats {
            lambda: lambda_max,
            ..Default::default()
        }],
        total_time: 0.0,
        loss,
        kind: ScreeningKind::Hessian,
        converged: true,
    };

    let mut lambda = lambda_max;
    let mut active: Vec<usize> = Vec::new();
    let mut scratch_u = vec![0.0; n];
    for _step in 0..settings.max_steps {
        if lambda <= lambda_min {
            break;
        }
        // Direction v = H⁻¹ sign(β_A) and the per-predictor correlation
        // slopes d_j = xⱼᵀ X_A v (§3.3: exact within the linear region).
        let tr_active = tracker.active().to_vec();
        let signs: Vec<f64> = tr_active.iter().map(|&j| state.beta[j].signum()).collect();
        let v = tracker.q_times(&signs);
        scratch_u.iter_mut().for_each(|x| *x = 0.0);
        for (idx, &j) in tr_active.iter().enumerate() {
            design.col_axpy(j, v[idx], &mut scratch_u);
        }

        // Next breakpoint: the largest λ' < λ where either
        //  (entering) c_j + (λ'−λ)·d_j = ±λ'  for some inactive j, or
        //  (leaving)  β_j + (λ−λ')·v_j = 0    for some active j.
        let mut next = lambda * settings.min_step;
        let is_active = {
            let mut m = vec![false; p];
            for &j in &active {
                m[j] = true;
            }
            m
        };
        for j in 0..p {
            if is_active[j] {
                continue;
            }
            let d = design.col_dot(j, &scratch_u);
            // c_j + (λ'−λ) d = s·λ'  ⇒  λ' = (c_j − λ d)/(s − d), s = ±1.
            for s in [1.0f64, -1.0] {
                let denom = s - d;
                if denom.abs() < 1e-12 {
                    continue;
                }
                let cand = (c[j] - lambda * d) / denom;
                if cand < lambda * (1.0 - 1e-10) && cand > next {
                    next = cand;
                }
            }
        }
        for (idx, &j) in tr_active.iter().enumerate() {
            if v[idx].abs() < 1e-14 {
                continue;
            }
            // β_j(λ') = β_j + (λ−λ')·v_j hits 0 at λ' = λ + β_j/v_j.
            let cand = lambda + state.beta[j] / v[idx];
            if cand < lambda * (1.0 - 1e-10) && cand > next {
                next = cand;
            }
        }
        // Step just past the breakpoint.
        let next = (next * (1.0 - settings.overshoot)).max(lambda_min);

        // Warm start (exact within the region) + solve.
        for (idx, &j) in tr_active.iter().enumerate() {
            state.beta[j] += (lambda - next) * v[idx];
        }
        let mut working: Vec<usize> = active.clone();
        // Candidates predicted to enter at `next` (small cushion).
        for j in 0..p {
            if !is_active[j] {
                let d = design.col_dot(j, &scratch_u);
                let est = c[j] + (next - lambda) * d;
                if est.abs() >= next * 0.999 {
                    working.push(j);
                }
            }
        }
        let mut st = StepStats {
            lambda: next,
            screened: working.len(),
            ..Default::default()
        };
        loop {
            let res = solve_subproblem(
                design,
                y,
                loss,
                next,
                &working,
                &mut state,
                &col_sq_norms,
                zeta,
                &settings.base.cd,
                &mut rng,
            );
            st.passes += res.passes;
            // Full KKT check.
            let mut violations = Vec::new();
            for j in 0..p {
                c[j] = design.col_dot(j, &state.resid);
                if state.beta[j] == 0.0 && c[j].abs() > next && !working.contains(&j) {
                    violations.push(j);
                }
            }
            st.full_sweeps += 1;
            if violations.is_empty() && res.converged {
                break;
            }
            st.violations += violations.len();
            working.extend(violations);
        }
        active = state.active_set();
        st.active = active.len();
        st.screened_final = working.len();
        if tracker.dim() > 0 {
            tracker.update(design, &active, None);
        } else {
            tracker.rebuild(design, &active, None);
        }
        let dev_ratio = 1.0 - loss.deviance(y, &state.eta) / null_dev.max(1e-300);
        st.dev_ratio = dev_ratio;
        fit.lambdas.push(next);
        fit.betas
            .push(active.iter().map(|&j| (j, state.beta[j])).collect());
        fit.dev_ratios.push(dev_ratio);
        fit.steps.push(st);
        lambda = next;
        if dev_ratio >= settings.base.dev_ratio_max || active.len() >= n.min(p) {
            break;
        }
    }
    fit.total_time = t_total.elapsed().as_secs_f64();
    fit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::path::PathFitter;

    #[test]
    fn homotopy_path_decreasing_and_converges() {
        let data = SyntheticSpec::new(60, 30, 4).snr(3.0).seed(21).generate();
        let fit = fit_approximate_homotopy(&data.design, &data.response, &Default::default());
        assert!(fit.lambdas.len() > 3);
        for w in fit.lambdas.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!(*fit.dev_ratios.last().unwrap() > 0.3);
    }

    #[test]
    fn homotopy_solution_matches_fixed_grid_at_same_lambda() {
        let data = SyntheticSpec::new(80, 20, 3).snr(4.0).seed(22).generate();
        let hom = fit_approximate_homotopy(&data.design, &data.response, &Default::default());
        // Refit on the homotopy's own grid with the standard driver and
        // compare coefficients.
        let mut settings = PathSettings::default();
        settings.lambda_path = Some(hom.lambdas.clone());
        let grid = PathFitter::new(Loss::Gaussian, ScreeningKind::Working)
            .with_settings(settings)
            .fit(&data.design, &data.response);
        let m = hom.lambdas.len().min(grid.lambdas.len());
        for k in 0..m {
            let a = hom.beta_dense(k, 20);
            let b = grid.beta_dense(k, 20);
            for j in 0..20 {
                assert!(
                    (a[j] - b[j]).abs() < 5e-3,
                    "step {k} coef {j}: {} vs {}",
                    a[j],
                    b[j]
                );
            }
        }
    }

    #[test]
    fn homotopy_places_more_grid_where_active_set_churns() {
        let data = SyntheticSpec::new(100, 40, 8).snr(3.0).seed(23).generate();
        let fit = fit_approximate_homotopy(&data.design, &data.response, &Default::default());
        // More steps than the number of distinct support sizes would be
        // wasteful; fewer would miss breakpoints. Sanity window:
        assert!(fit.lambdas.len() >= 5);
        assert!(fit.lambdas.len() <= 500);
    }
}
