//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! The paper's Appendix C preconditions the Hessian through its spectral
//! decomposition H = QΛQᵀ, adding α to the diagonal when
//! min(diag Λ) < α with α = n·10⁻⁴ and using Q(αI + Λ)⁻¹Qᵀ in place of
//! the true inverse. Jacobi is slow for large matrices but the Hessian
//! here has dimension |A| (the active set), typically ≤ a few hundred,
//! where Jacobi's simplicity and unconditional robustness win.

use super::DenseMatrix;

/// Eigendecomposition A = Q Λ Qᵀ (A symmetric).
pub struct SymEigen {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Orthogonal matrix of eigenvectors (columns match `values`).
    pub vectors: DenseMatrix,
}

impl SymEigen {
    /// Cyclic Jacobi with threshold sweeping. `a` must be symmetric;
    /// only O(n²) extra storage.
    pub fn factor(a: &DenseMatrix) -> Self {
        assert_eq!(a.nrows(), a.ncols(), "matrix must be square");
        let n = a.nrows();
        let mut m = a.clone();
        let mut q = DenseMatrix::identity(n);
        if n <= 1 {
            return Self {
                values: (0..n).map(|i| m.at(i, i)).collect(),
                vectors: q,
            };
        }
        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            // Off-diagonal Frobenius norm.
            let mut off = 0.0;
            for i in 0..n {
                for j in i + 1..n {
                    off += m.at(i, j).powi(2);
                }
            }
            let scale = (0..n).map(|i| m.at(i, i).abs()).fold(1e-300, f64::max);
            if off.sqrt() <= 1e-14 * scale * n as f64 {
                break;
            }
            for p in 0..n - 1 {
                for r in p + 1..n {
                    let apr = m.at(p, r);
                    if apr.abs() <= 1e-300 {
                        continue;
                    }
                    let app = m.at(p, p);
                    let arr = m.at(r, r);
                    // Rotation angle: tan(2θ) = 2a_pr / (a_pp − a_rr).
                    let theta = 0.5 * (2.0 * apr).atan2(app - arr);
                    let c = theta.cos();
                    let s = theta.sin();
                    // Apply the Givens rotation G(p, r, θ) from both
                    // sides of m and on the right of q.
                    for k in 0..n {
                        let mkp = m.at(k, p);
                        let mkr = m.at(k, r);
                        *m.at_mut(k, p) = c * mkp + s * mkr;
                        *m.at_mut(k, r) = -s * mkp + c * mkr;
                    }
                    for k in 0..n {
                        let mpk = m.at(p, k);
                        let mrk = m.at(r, k);
                        *m.at_mut(p, k) = c * mpk + s * mrk;
                        *m.at_mut(r, k) = -s * mpk + c * mrk;
                    }
                    for k in 0..n {
                        let qkp = q.at(k, p);
                        let qkr = q.at(k, r);
                        *q.at_mut(k, p) = c * qkp + s * qkr;
                        *q.at_mut(k, r) = -s * qkp + c * qkr;
                    }
                }
            }
        }
        // Collect and sort ascending, permuting eigenvectors along.
        let mut order: Vec<usize> = (0..n).collect();
        let vals: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
        order.sort_by(|&i, &j| vals[i].total_cmp(&vals[j]));
        let values: Vec<f64> = order.iter().map(|&i| vals[i]).collect();
        let mut vectors = DenseMatrix::zeros(n, n);
        for (jj, &j) in order.iter().enumerate() {
            vectors.col_mut(jj).copy_from_slice(q.col(j));
        }
        Self { values, vectors }
    }

    pub fn min_eigenvalue(&self) -> f64 {
        self.values.first().copied().unwrap_or(0.0)
    }

    /// Reconstruct Q f(Λ) Qᵀ for an eigenvalue map `f` — this is how the
    /// preconditioned inverse Q(αI + Λ)⁻¹Qᵀ of Appendix C is built.
    pub fn apply_spectral(&self, f: impl Fn(f64) -> f64) -> DenseMatrix {
        let n = self.values.len();
        let mut out = DenseMatrix::zeros(n, n);
        for k in 0..n {
            let fk = f(self.values[k]);
            if fk == 0.0 {
                continue;
            }
            let qk = self.vectors.col(k);
            for j in 0..n {
                let w = fk * qk[j];
                let col = out.col_mut(j);
                for i in 0..n {
                    col[i] += w * qk[i];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn random_sym(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_gaussian();
                *a.at_mut(i, j) = v;
                *a.at_mut(j, i) = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = DenseMatrix::zeros(3, 3);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = -1.0;
        *a.at_mut(2, 2) = 2.0;
        let e = SymEigen::factor(&a);
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3.
        let mut a = DenseMatrix::zeros(2, 2);
        *a.at_mut(0, 0) = 2.0;
        *a.at_mut(1, 1) = 2.0;
        *a.at_mut(0, 1) = 1.0;
        *a.at_mut(1, 0) = 1.0;
        let e = SymEigen::factor(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let a = random_sym(10, 7);
        let e = SymEigen::factor(&a);
        let rec = e.apply_spectral(|x| x);
        assert!(rec.max_abs_diff(&a) < 1e-9, "reconstruction");
        let qtq = e.vectors.t_gemm(&e.vectors);
        assert!(qtq.max_abs_diff(&DenseMatrix::identity(10)) < 1e-10, "Q orthogonal");
    }

    #[test]
    fn spectral_inverse() {
        let mut a = random_sym(6, 9);
        // make SPD
        let g = a.t_gemm(&a);
        a = g;
        for i in 0..6 {
            *a.at_mut(i, i) += 1.0;
        }
        let e = SymEigen::factor(&a);
        assert!(e.min_eigenvalue() > 0.0);
        let inv = e.apply_spectral(|x| 1.0 / x);
        let prod = a.gemm(&inv);
        assert!(prod.max_abs_diff(&DenseMatrix::identity(6)) < 1e-8);
    }

    #[test]
    fn preconditioner_shifts_small_eigenvalues() {
        // Appendix C behaviour: eigenvalues below alpha get shifted.
        let mut a = DenseMatrix::zeros(2, 2);
        *a.at_mut(0, 0) = 1e-9;
        *a.at_mut(1, 1) = 5.0;
        let e = SymEigen::factor(&a);
        let alpha = 0.01;
        let pinv = e.apply_spectral(|x| 1.0 / (x + alpha));
        // (1e-9 + 0.01)^-1 ≈ 100, finite; plain inverse would be 1e9.
        assert!(pinv.at(0, 0) < 101.0);
        assert!(pinv.at(0, 0) > 99.0);
    }

    #[test]
    fn handles_size_one_and_zero() {
        let a = DenseMatrix::from_col_major(1, 1, vec![4.0]);
        let e = SymEigen::factor(&a);
        assert_eq!(e.values, vec![4.0]);
        let z = DenseMatrix::zeros(0, 0);
        let e0 = SymEigen::factor(&z);
        assert!(e0.values.is_empty());
    }
}
