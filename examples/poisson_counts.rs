//! ℓ₁-regularized Poisson regression (paper Appendix F.9): count
//! responses, the loss with no Lipschitz gradient — Gap-Safe-based
//! machinery is automatically disabled and the Hessian rule still
//! applies (it only needs twice-differentiability, §5).
//!
//!     cargo run --release --example poisson_counts

use hessian_screening::metrics::{fmt_secs, Table};
use hessian_screening::prelude::*;

fn main() {
    let data = SyntheticSpec::new(500, 1_000, 10)
        .rho(0.15)
        .snr(2.0)
        .loss(Loss::Poisson)
        .signal_scale(0.3)
        .seed(5)
        .generate();
    let mean_count =
        data.response.iter().sum::<f64>() / data.response.len() as f64;
    println!(
        "workload: n={} p={} Poisson counts (mean y = {:.2})\n",
        data.n(),
        data.p(),
        mean_count
    );

    let mut table = Table::new(&["method", "time (s)", "passes", "steps", "final dev ratio"]);
    let mut fits = Vec::new();
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
        let fit = PathFitter::new(Loss::Poisson, kind).fit(&data.design, &data.response);
        table.row(vec![
            kind.name().into(),
            fmt_secs(fit.total_time),
            format!("{}", fit.total_passes()),
            format!("{}", fit.lambdas.len()),
            format!("{:.4}", fit.dev_ratios.last().unwrap()),
        ]);
        fits.push(fit);
    }
    println!("{}", table.render());

    // Methods must agree on the path.
    let p = data.p();
    let m = fits[0].lambdas.len().min(fits[1].lambdas.len());
    let mut worst = 0.0f64;
    for k in 0..m {
        let a = fits[0].beta_dense(k, p);
        let b = fits[1].beta_dense(k, p);
        for j in 0..p {
            worst = worst.max((a[j] - b[j]).abs());
        }
    }
    println!("cross-method max |Δβ|: {worst:.2e}");
    assert!(worst < 1e-2);
    println!("Poisson path OK — Hessian rule applies beyond the Lipschitz losses.");
}
