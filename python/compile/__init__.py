"""Layer-2 compile package: JAX model graphs (`model`), Pallas kernels
(`kernels`), and the AOT lowering driver (`aot`) that turns them into
HLO-text artifacts for the rust PJRT backend."""
