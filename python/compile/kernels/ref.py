"""Pure-jnp oracles for the Pallas kernels (Layer 1 correctness).

Every kernel in this package has an entry here; pytest (and the
hypothesis sweeps in ``python/tests``) assert ``assert_allclose``
between the Pallas output and these references for a grid of shapes
and dtypes. These functions are also what the kernels *mean*: the
kernels are pure performance artifacts.
"""

import jax.numpy as jnp


def xt_r_ref(xt: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Correlation sweep c = Xᵀr.

    ``xt`` is X *transposed*, shape (p, n) — the rust coordinator stores
    X column-major (n, p), whose raw buffer is exactly a row-major
    (p, n) array, so the transposed convention makes the FFI zero-copy.
    ``r`` has shape (n, 1); the result has shape (p, 1).
    """
    return xt @ r


def gram_block_ref(xe_t: jnp.ndarray, w: jnp.ndarray, xd_t: jnp.ndarray) -> jnp.ndarray:
    """Weighted Gram panel G = X_Eᵀ D(w) X_D — the augmentation-step
    workload of the paper's Algorithm 1.

    ``xe_t``: (e, n); ``w``: (n, 1) Hessian weights; ``xd_t``: (d, n).
    Result: (e, d).
    """
    return xe_t @ (w * xd_t.T)


def lasso_kkt_ref(xt: jnp.ndarray, y: jnp.ndarray, eta: jnp.ndarray, lam):
    """Fused KKT sweep for the Gaussian lasso: residual, correlation and
    the per-predictor violation mask in one graph (the paper's §3.3.4
    "KKT checks" — the per-step O(np) hot spot).

    Returns (c, resid, viol) with shapes (p,1), (n,1), (p,1).
    """
    resid = y - eta
    c = xt @ resid
    viol = (jnp.abs(c) > lam).astype(xt.dtype)
    return c, resid, viol
