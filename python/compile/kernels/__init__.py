"""Layer-1 Pallas kernels for the Hessian-screening stack.

* ``xt_r`` — the correlation sweep c = Xᵀr (screening/KKT hot spot);
* ``gram_block`` — weighted Gram panels for the Algorithm-1 sweep
  updates;
* ``ref`` — pure-jnp oracles the kernels are tested against.
"""

from .gram_block import gram_block
from .xt_r import xt_r

__all__ = ["gram_block", "xt_r"]
