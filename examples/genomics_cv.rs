//! Gene-expression workflow: ℓ₁-regularized logistic regression on a
//! colon-cancer-like design (n ≪ p, correlated gene blocks) with
//! hold-out model selection along the path — the workload class that
//! motivates the paper's Table 1 genomics rows.
//!
//!     cargo run --release --example genomics_cv

use hessian_screening::data::{dataset_by_name, DesignMatrix};
use hessian_screening::loss::sigmoid;
use hessian_screening::metrics::Table;
use hessian_screening::prelude::*;
use hessian_screening::rng::Xoshiro256pp;

/// Mean held-out negative log-likelihood of a path step.
fn holdout_deviance(
    design: &DesignMatrix,
    y: &[f64],
    idx: &[usize],
    beta: &[(usize, f64)],
) -> f64 {
    let mut total = 0.0;
    for &i in idx {
        let mut eta = 0.0;
        for &(j, b) in beta {
            eta += design_at(design, i, j) * b;
        }
        let mu: f64 = sigmoid(eta);
        let e = 1e-12;
        total -= y[i] * (mu + e).ln() + (1.0 - y[i]) * (1.0 - mu + e).ln();
    }
    total / idx.len() as f64
}

fn design_at(design: &DesignMatrix, i: usize, j: usize) -> f64 {
    match design {
        DesignMatrix::Dense(m) => m.at(i, j),
        DesignMatrix::Sparse(m) => {
            let (ri, vals) = m.col(j);
            match ri.binary_search(&(i as u32)) {
                Ok(k) => vals[k],
                Err(_) => 0.0,
            }
        }
    }
}

fn main() {
    // The colon-cancer analogue: n=62, p=2000 gene-expression-like
    // blocks (see data::datasets for the substitution notes).
    let spec = dataset_by_name("colon-cancer").expect("catalog");
    let data = spec.generate(0);
    let n = data.n();
    println!("dataset: {} (n={}, p={})", data.name, n, data.p());

    // 75/25 split for hold-out selection.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let cut = (3 * n) / 4;
    let (train_idx, val_idx) = order.split_at(cut);

    // Build the training subproblem by masking rows: for this example we
    // refit on the training rows only (copy the sub-design densely —
    // n is tiny in this regime).
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => panic!("colon-cancer analogue is dense"),
    };
    let mut sub = hessian_screening::linalg::DenseMatrix::zeros(cut, data.p());
    let mut y_train = vec![0.0; cut];
    for (row, &i) in train_idx.iter().enumerate() {
        for j in 0..data.p() {
            *sub.at_mut(row, j) = dense.at(i, j);
        }
        y_train[row] = data.response[i];
    }
    let sub = DesignMatrix::Dense(sub);

    let fit = PathFitter::new(Loss::Logistic, ScreeningKind::Hessian).fit(&sub, &y_train);
    println!(
        "path: {} steps, {} CD passes, {:.3}s\n",
        fit.lambdas.len(),
        fit.total_passes(),
        fit.total_time
    );

    // Score every step on the held-out rows.
    let mut best = (0usize, f64::INFINITY);
    let mut table = Table::new(&["step", "lambda", "active", "holdout nll"]);
    for k in 0..fit.lambdas.len() {
        let nll = holdout_deviance(&data.design, &data.response, val_idx, &fit.betas[k]);
        if nll < best.1 {
            best = (k, nll);
        }
        if k % 10 == 0 {
            table.row(vec![
                format!("{k}"),
                format!("{:.4}", fit.lambdas[k]),
                format!("{}", fit.betas[k].len()),
                format!("{:.4}", nll),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "selected step {} (lambda={:.4}) with {} genes, holdout NLL {:.4}",
        best.0,
        fit.lambdas[best.0],
        fit.betas[best.0].len(),
        best.1
    );
    let null_nll = holdout_deviance(&data.design, &data.response, val_idx, &[]);
    println!("null model holdout NLL: {null_nll:.4}");
    assert!(best.1 < null_nll, "selected model must beat the null model");
}
