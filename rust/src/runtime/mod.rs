//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text) and executes them on the PJRT CPU
//! client via the `xla` crate — the bridge that keeps Python off the
//! solve path entirely.
//!
//! The [`RuntimeEngine`] compiles every artifact in `artifacts/` at
//! startup (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile`), keyed by (op, shape). Designs are *registered*
//! once — converted to f32 and uploaded as device buffers — so a KKT
//! sweep at solve time moves only the O(n) residual across the FFI.
//!
//! Precision note: artifacts run in f32 while the native solver is f64.
//! [`EngineSweep::full_sweep`] therefore re-verifies every *borderline*
//! correlation (within 0.1% of the screening threshold) with the native
//! f64 path, so KKT decisions never depend on f32 rounding.

use crate::linalg::Design;
use crate::loss::Loss;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One compiled artifact.
struct CompiledOp {
    exe: xla::PjRtLoadedExecutable,
}

/// A design uploaded to the PJRT device (f32, shape (p, n) row-major —
/// byte-identical to the coordinator's column-major (n, p) storage).
pub struct RegisteredDesign {
    buffer: xla::PjRtBuffer,
    pub n: usize,
    pub p: usize,
}

/// The PJRT execution engine.
pub struct RuntimeEngine {
    client: xla::PjRtClient,
    ops: HashMap<(String, String), CompiledOp>,
}

impl RuntimeEngine {
    /// Load and compile every artifact listed in `dir`/manifest.tsv.
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut ops = HashMap::new();
        for line in text.lines() {
            let parts: Vec<&str> = line.trim().split('\t').collect();
            if parts.len() != 4 {
                continue;
            }
            let (op, key, _dtype, fname) = (parts[0], parts[1], parts[2], parts[3]);
            let path = dir.join(fname);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {fname}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {fname}: {e:?}"))?;
            ops.insert((op.to_string(), key.to_string()), CompiledOp { exe });
        }
        if ops.is_empty() {
            return Err(anyhow!("no artifacts found in {}", dir.display()));
        }
        Ok(Self { client, ops })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Self> {
        Self::load_dir(Path::new("artifacts"))
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn has(&self, op: &str, key: &str) -> bool {
        self.ops.contains_key(&(op.to_string(), key.to_string()))
    }

    fn shape_key(n: usize, p: usize) -> String {
        format!("{n}x{p}")
    }

    /// Whether a KKT sweep artifact exists for this loss and shape.
    pub fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        let op = match loss {
            Loss::Gaussian => "lasso_kkt",
            Loss::Logistic => "logistic_kkt",
            Loss::Poisson => return false,
        };
        self.has(op, &Self::shape_key(n, p))
    }

    /// Upload a design (as its raw column-major f64 buffer) to the
    /// device, converting to f32. O(np), once per dataset.
    pub fn register_design(
        &self,
        col_major: &[f64],
        n: usize,
        p: usize,
    ) -> Result<RegisteredDesign> {
        assert_eq!(col_major.len(), n * p);
        let f32data: Vec<f32> = col_major.iter().map(|&v| v as f32).collect();
        // Column-major (n, p) == row-major (p, n): upload with dims (p, n).
        let buffer = self
            .client
            .buffer_from_host_buffer(&f32data, &[p, n], None)
            .map_err(|e| anyhow!("uploading design: {e:?}"))?;
        Ok(RegisteredDesign { buffer, n, p })
    }

    /// c = Xᵀr through the `xt_r` artifact. Returns None when no
    /// artifact matches the shape.
    pub fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let key = Self::shape_key(design.n, design.p);
        let Some(op) = self.ops.get(&("xt_r".to_string(), key)) else {
            return Ok(None);
        };
        let rf: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        let rbuf = self
            .client
            .buffer_from_host_buffer(&rf, &[design.n, 1], None)
            .map_err(|e| anyhow!("uploading r: {e:?}"))?;
        let out = op
            .exe
            .execute_b(&[&design.buffer, &rbuf])
            .map_err(|e| anyhow!("execute xt_r: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(Some(v.into_iter().map(|x| x as f64).collect()))
    }

    /// Fused KKT sweep via `lasso_kkt`/`logistic_kkt`. Returns
    /// (c, resid) in f64, or None when no artifact matches.
    pub fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let opname = match loss {
            Loss::Gaussian => "lasso_kkt",
            Loss::Logistic => "logistic_kkt",
            Loss::Poisson => return Ok(None),
        };
        let key = Self::shape_key(design.n, design.p);
        let Some(op) = self.ops.get(&(opname.to_string(), key)) else {
            return Ok(None);
        };
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let ef: Vec<f32> = eta.iter().map(|&v| v as f32).collect();
        let ybuf = self
            .client
            .buffer_from_host_buffer(&yf, &[design.n, 1], None)
            .map_err(|e| anyhow!("uploading y: {e:?}"))?;
        let ebuf = self
            .client
            .buffer_from_host_buffer(&ef, &[design.n, 1], None)
            .map_err(|e| anyhow!("uploading eta: {e:?}"))?;
        let lbuf = self
            .client
            .buffer_from_host_buffer(&[lambda as f32], &[], None)
            .map_err(|e| anyhow!("uploading lambda: {e:?}"))?;
        let out = op
            .exe
            .execute_b(&[&design.buffer, &ybuf, &ebuf, &lbuf])
            .map_err(|e| anyhow!("execute {opname}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (c_l, r_l, _viol) = lit.to_tuple3().map_err(|e| anyhow!("untuple3: {e:?}"))?;
        let c: Vec<f32> = c_l.to_vec().map_err(|e| anyhow!("c to_vec: {e:?}"))?;
        let r: Vec<f32> = r_l.to_vec().map_err(|e| anyhow!("r to_vec: {e:?}"))?;
        Ok(Some((
            c.into_iter().map(|x| x as f64).collect(),
            r.into_iter().map(|x| x as f64).collect(),
        )))
    }

    /// Weighted Gram panel via `gram_block` (Algorithm-1 augmentation).
    /// `xe_t`/`xd_t` are (e, n)/(d, n) row-major f64 slices.
    pub fn gram_block(
        &self,
        xe_t: &[f64],
        w: &[f64],
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        let key = format!("{e}x{d}x{n}");
        let Some(op) = self.ops.get(&("gram_block".to_string(), key)) else {
            return Ok(None);
        };
        let to32 = |s: &[f64]| s.iter().map(|&v| v as f32).collect::<Vec<f32>>();
        let eb = self
            .client
            .buffer_from_host_buffer(&to32(xe_t), &[e, n], None)
            .map_err(|er| anyhow!("upload xe: {er:?}"))?;
        let wb = self
            .client
            .buffer_from_host_buffer(&to32(w), &[n, 1], None)
            .map_err(|er| anyhow!("upload w: {er:?}"))?;
        let db = self
            .client
            .buffer_from_host_buffer(&to32(xd_t), &[d, n], None)
            .map_err(|er| anyhow!("upload xd: {er:?}"))?;
        let out = op
            .exe
            .execute_b(&[&eb, &wb, &db])
            .map_err(|er| anyhow!("execute gram_block: {er:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|er| anyhow!("fetch: {er:?}"))?
            .to_tuple1()
            .map_err(|er| anyhow!("untuple: {er:?}"))?;
        let v: Vec<f32> = lit.to_vec().map_err(|er| anyhow!("to_vec: {er:?}"))?;
        Ok(Some(v.into_iter().map(|x| x as f64).collect()))
    }
}

/// An engine bound to one registered design: what the path driver uses
/// for its full KKT sweeps ([`crate::path::PathFitter::fit_with_engine`]).
pub struct EngineSweep<'a> {
    pub engine: &'a RuntimeEngine,
    pub design: RegisteredDesign,
    pub loss: Loss,
    /// Borderline band re-verified in f64 (fraction of λ).
    pub recheck_band: f64,
}

impl<'a> EngineSweep<'a> {
    /// Bind `engine` to a dense design; returns None when the engine
    /// has no sweep artifact for this (loss, n, p).
    pub fn new(
        engine: &'a RuntimeEngine,
        design: &crate::linalg::DenseMatrix,
        loss: Loss,
    ) -> Result<Option<Self>> {
        let (n, p) = (design.nrows(), design.ncols());
        if !engine.supports_sweep(loss, n, p) {
            return Ok(None);
        }
        let reg = engine.register_design(design.data(), n, p)?;
        Ok(Some(Self {
            engine,
            design: reg,
            loss,
            recheck_band: 1e-3,
        }))
    }

    /// Full correlation sweep through the artifact, with native f64
    /// re-verification of the borderline band around λ. Returns false
    /// (leaving `c` untouched) when the artifact path is unavailable,
    /// in which case the caller falls back to the native sweep.
    pub fn full_sweep<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        lambda: f64,
        c: &mut [f64],
    ) -> bool {
        match self.engine.kkt_sweep(self.loss, &self.design, y, eta, lambda) {
            Ok(Some((c32, _resid32))) => {
                debug_assert_eq!(c32.len(), c.len());
                let lo = lambda * (1.0 - self.recheck_band);
                let hi = lambda * (1.0 + self.recheck_band);
                for (j, cv) in c32.into_iter().enumerate() {
                    let a = cv.abs();
                    c[j] = if a >= lo && a <= hi {
                        // f32 can't be trusted at the threshold: f64 it.
                        native.col_dot(j, resid)
                    } else {
                        cv
                    };
                }
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full engine integration tests live in rust/tests/ (they need
    // `make artifacts`). Here: pure logic.

    #[test]
    fn shape_key_format() {
        assert_eq!(RuntimeEngine::shape_key(200, 2000), "200x2000");
    }

    #[test]
    fn manifest_missing_is_error() {
        let err = RuntimeEngine::load_dir(Path::new("/nonexistent-dir-xyz"));
        assert!(err.is_err());
    }
}
