# Build/test entry points referenced throughout the docs and the
# integration tests (rust/tests/runtime_roundtrip.rs).
#
#   make artifacts   lower the L2 graphs to HLO text (needs jax)
#   make build       release build, default features (pure Rust)
#   make test        build artifacts when possible, then cargo test
#   make bench       run the experiment benches (quick presets)
#   make ci          mirror the CI workflow locally
#   make clean       remove build products

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR := artifacts

.PHONY: all build test test-rust artifacts bench ci fmt clippy clean

all: build

build:
	$(CARGO) build --release

# AOT artifacts for the PJRT backend. Requires a Python with jax
# installed; skipped gracefully by `make test` when unavailable.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

# Full test entry point: build artifacts when the Python toolchain is
# present (the PJRT tests skip politely otherwise), then run the crate
# tests.
test:
	-$(MAKE) artifacts
	$(CARGO) test -q

# Crate tests only — what tier-1 CI runs on a fresh checkout.
test-rust:
	$(CARGO) test -q

bench:
	$(CARGO) bench

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace -- -D warnings

# Mirror .github/workflows/ci.yml locally.
ci: fmt clippy
	$(CARGO) build --release --workspace
	$(CARGO) test -q
	$(CARGO) bench --no-run
	$(CARGO) check --workspace --features pjrt

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR) results
	find python -name __pycache__ -type d -exec rm -rf {} +
