//! Minimal property-based testing framework.
//!
//! `proptest`/`quickcheck` are not in the offline crate cache, so this
//! module provides the 10% we need: seeded generators for the domain
//! objects (dimensions, correlation levels, design matrices, coefficient
//! vectors) and a `forall` driver that runs a property over many random
//! cases and reports the failing seed so a case can be replayed
//! deterministically.

use crate::linalg::DenseMatrix;
use crate::rng::{derive_seed, Xoshiro256pp};

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 32,
            seed: 0x5EED_CAFE,
        }
    }
}

/// Run `prop` on `cfg.cases` independently seeded RNGs. On failure
/// (panic or `Err`), re-raise with the case index and seed so the case
/// is replayable via `Gen::new(seed)`.
pub fn forall<F>(cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = derive_seed(cfg.seed, case as u64);
        let mut g = Gen::new(seed);
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g))) {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!("property failed at case {case} (seed {seed:#x}): {msg}"),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "panic".to_string());
                panic!("property panicked at case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

/// A seeded generator of domain objects.
pub struct Gen {
    pub rng: Xoshiro256pp,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256pp::seed_from_u64(seed),
        }
    }

    /// Integer in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// One of the provided values.
    pub fn choose<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.rng.next_below(xs.len())]
    }

    /// Vector of i.i.d. standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_gaussian(&mut v);
        v
    }

    /// Random dense n×p design with i.i.d. N(0,1) entries.
    pub fn gaussian_matrix(&mut self, n: usize, p: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, p);
        self.rng.fill_gaussian(m.data_mut());
        m
    }

    /// Sparse coefficient vector with `s` non-zeros in ±[0.5, 2].
    pub fn sparse_coefs(&mut self, p: usize, s: usize) -> Vec<f64> {
        let mut beta = vec![0.0; p];
        let idx = self.rng.sample_indices(p, s.min(p));
        for j in idx {
            let mag = self.f64_in(0.5, 2.0);
            beta[j] = if self.rng.next_bernoulli(0.5) { mag } else { -mag };
        }
        beta
    }
}

/// Assert |a − b| ≤ atol + rtol·|b|, with a readable message.
pub fn close(a: f64, b: f64, atol: f64, rtol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs() {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (diff {})", (a - b).abs()))
    }
}

/// Assert two slices are element-wise close.
pub fn all_close(a: &[f64], b: &[f64], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, atol, rtol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(Config { cases: 8, seed: 1 }, |g| {
            let n = g.usize_in(1, 10);
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(Config { cases: 8, seed: 2 }, |g| {
            let v = g.f64_in(0.0, 1.0);
            if v < 2.0 && v >= 0.5 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property panicked")]
    fn forall_reports_panics() {
        forall(Config { cases: 4, seed: 3 }, |_g| {
            panic!("inner panic");
        });
    }

    #[test]
    fn generators_in_range() {
        let mut g = Gen::new(7);
        for _ in 0..100 {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let c = g.choose(&[10, 20, 30]);
            assert!([10, 20, 30].contains(&c));
        }
    }

    #[test]
    fn sparse_coefs_support_size() {
        let mut g = Gen::new(9);
        let beta = g.sparse_coefs(50, 7);
        let nnz = beta.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 7);
        for &b in &beta {
            assert!(b == 0.0 || (0.5..=2.0).contains(&b.abs()));
        }
    }

    #[test]
    fn close_helpers() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0).is_ok());
        assert!(close(1.0, 2.0, 1e-9, 0.0).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 0.0, 0.0).is_err());
    }
}
