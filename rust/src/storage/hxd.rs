//! The `.hxd` on-disk columnar design format.
//!
//! Byte layout (all integers little-endian):
//!
//! ```text
//! offset  size            field
//! ------  --------------  ------------------------------------------
//!      0  8               magic  b"HXDESIGN"
//!      8  4               format version (u32, currently 1)
//!     12  4               endianness sentinel (u32 0x01020304)
//!     16  8               n   (u64, rows)
//!     24  8               p   (u64, columns)
//!     32  8               block_cols (u64, checksum/cache granule)
//!     40  8               flags (bit 0: response present,
//!                                bits 1..=2: loss tag 0/1/2)
//!     48  n·p·8           column-major f64 data; column c starts at
//!                         48 + c·n·8 (blocks set checksum and cache
//!                         granularity only — the data is contiguous)
//!      …  nblocks·8       per-block FNV-1a-64 checksums      ┐
//!      …  p·8             per-column ℓ2 norms (f64)          │ the
//!      …  [n·8]           response vector, if flagged        │ manifest
//!      …  8               FNV-1a-64 of the manifest bytes    │
//!      …  8               tail magic b"HXDTAIL\0"            ┘
//! ```
//!
//! `nblocks = ceil(p / block_cols)`; the last block may be ragged. The
//! total file size is computable from the header alone, so truncation
//! is detected at open time; block corruption is detected at read time
//! (every block read is checksummed before it is served); manifest
//! corruption is detected at open time via the trailing manifest hash.
//!
//! Norms are computed by the writer with the same `blas::nrm2` kernel
//! the resident path uses, so a design registered from an `.hxd` file
//! carries bitwise-identical `col_norms` — a requirement, not a nicety:
//! the sharded keep-mask rebuild consumes them.

#![forbid(unsafe_code)]

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::{check_range, fnv1a64, fnv1a64_update, ColumnSource};
use crate::error::Result;
use crate::linalg::{blas, DenseMatrix};
use crate::loss::Loss;

/// Leading file magic.
pub const HXD_MAGIC: [u8; 8] = *b"HXDESIGN";
/// Trailing tail marker (a cheap torn-write detector).
pub const HXD_TAIL: [u8; 8] = *b"HXDTAIL\0";
/// Format version this reader/writer speaks.
pub const HXD_VERSION: u32 = 1;
/// Default checksum/cache block width for `hx pack`.
pub const DEFAULT_BLOCK_COLS: usize = 64;

const ENDIAN_SENTINEL: u32 = 0x0102_0304;
const HEADER_LEN: usize = 48;
const FLAG_RESPONSE: u64 = 1;
const KNOWN_FLAGS: u64 = 0b111;

/// `ceil(a / b)` for b > 0 (MSRV predates `usize::div_ceil`).
fn div_ceil(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

fn loss_tag(loss: Loss) -> u64 {
    match loss {
        Loss::Gaussian => 0,
        Loss::Logistic => 1,
        Loss::Poisson => 2,
    }
}

fn loss_from_tag(tag: u64) -> Result<Loss> {
    match tag {
        0 => Ok(Loss::Gaussian),
        1 => Ok(Loss::Logistic),
        2 => Ok(Loss::Poisson),
        other => Err(crate::err!("unknown loss tag {other} in .hxd flags")),
    }
}

fn u32_at(buf: &[u8], off: usize) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&buf[off..off + 4]);
    u32::from_le_bytes(b)
}

fn u64_at(buf: &[u8], off: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[off..off + 8]);
    u64::from_le_bytes(b)
}

fn f64_from_le(chunk: &[u8]) -> f64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(chunk);
    f64::from_le_bytes(b)
}

fn to_usize(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| crate::err!("{what} = {v} does not fit in usize"))
}

/// What `pack_dense`/[`HxdWriter::finish`] report back.
#[derive(Clone, Debug)]
pub struct PackSummary {
    pub n: usize,
    pub p: usize,
    pub block_cols: usize,
    /// Number of checksum blocks written (`ceil(p / block_cols)`).
    pub blocks: usize,
    /// Total file size in bytes.
    pub bytes: u64,
}

/// Streaming `.hxd` writer: columns go out in arrival order with
/// incremental per-block checksums, so packing never needs a second
/// resident copy of the design.
pub struct HxdWriter {
    file: BufWriter<File>,
    path: PathBuf,
    n: usize,
    p: usize,
    block_cols: usize,
    loss: Loss,
    cols_written: usize,
    cols_in_block: usize,
    block_hash: u64,
    block_sums: Vec<u64>,
    col_norms: Vec<f64>,
    buf: Vec<u8>,
}

impl HxdWriter {
    /// Create `path` and write the fixed header. The flags word is
    /// patched at [`HxdWriter::finish`], when the response is known.
    pub fn create(path: &Path, n: usize, p: usize, block_cols: usize, loss: Loss) -> Result<Self> {
        if n == 0 || p == 0 {
            return Err(crate::err!("cannot pack an empty design ({n}x{p})"));
        }
        if block_cols == 0 {
            return Err(crate::err!("block width must be at least 1 column"));
        }
        (n as u64)
            .checked_mul(p as u64)
            .and_then(|v| v.checked_mul(8))
            .ok_or_else(|| crate::err!("design shape {n}x{p} overflows the 64-bit file layout"))?;
        let file = File::create(path)
            .map_err(|e| crate::err!("creating {}: {e}", path.display()))?;
        let mut header = [0u8; HEADER_LEN];
        header[..8].copy_from_slice(&HXD_MAGIC);
        header[8..12].copy_from_slice(&HXD_VERSION.to_le_bytes());
        header[12..16].copy_from_slice(&ENDIAN_SENTINEL.to_le_bytes());
        header[16..24].copy_from_slice(&(n as u64).to_le_bytes());
        header[24..32].copy_from_slice(&(p as u64).to_le_bytes());
        header[32..40].copy_from_slice(&(block_cols as u64).to_le_bytes());
        // header[40..48] (flags) stays zero until finish().
        let mut w = Self {
            file: BufWriter::new(file),
            path: path.to_path_buf(),
            n,
            p,
            block_cols,
            loss,
            cols_written: 0,
            cols_in_block: 0,
            block_hash: fnv1a64(b""),
            block_sums: Vec::with_capacity(div_ceil(p, block_cols)),
            col_norms: Vec::with_capacity(p),
            buf: Vec::with_capacity(8 * n),
        };
        w.write_bytes(&header)?;
        Ok(w)
    }

    fn write_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        self.file
            .write_all(bytes)
            .map_err(|e| crate::err!("writing {}: {e}", self.path.display()))
    }

    /// Append whole columns (a column-major panel of `w·n` values).
    pub fn write_cols(&mut self, panel: &[f64]) -> Result<()> {
        if panel.len() % self.n != 0 {
            return Err(crate::err!(
                "panel of {} values is not a whole number of n = {} columns",
                panel.len(),
                self.n
            ));
        }
        let w = panel.len() / self.n;
        if self.cols_written + w > self.p {
            return Err(crate::err!(
                "writing {w} more column(s) would exceed p = {} ({} already packed)",
                self.p,
                self.cols_written
            ));
        }
        for col in panel.chunks_exact(self.n) {
            self.col_norms.push(blas::nrm2(col));
            self.buf.clear();
            for &v in col {
                self.buf.extend_from_slice(&v.to_le_bytes());
            }
            self.block_hash = fnv1a64_update(self.block_hash, &self.buf);
            let bytes = std::mem::take(&mut self.buf);
            self.write_bytes(&bytes)?;
            self.buf = bytes;
            self.cols_written += 1;
            self.cols_in_block += 1;
            if self.cols_in_block == self.block_cols {
                self.block_sums.push(self.block_hash);
                self.block_hash = fnv1a64(b"");
                self.cols_in_block = 0;
            }
        }
        Ok(())
    }

    /// Seal the file: flush the ragged tail block, write the manifest
    /// (checksums, norms, optional response, manifest hash, tail
    /// marker) and patch the header flags.
    pub fn finish(mut self, response: Option<&[f64]>) -> Result<PackSummary> {
        if self.cols_written != self.p {
            return Err(crate::err!(
                "packed only {} of {} columns before finish",
                self.cols_written,
                self.p
            ));
        }
        if self.cols_in_block > 0 {
            self.block_sums.push(self.block_hash);
        }
        if let Some(y) = response {
            if y.len() != self.n {
                return Err(crate::err!(
                    "response has {} entries, expected n = {}",
                    y.len(),
                    self.n
                ));
            }
        }
        let mut manifest =
            Vec::with_capacity(8 * (self.block_sums.len() + self.p + self.n) + 16);
        for &h in &self.block_sums {
            manifest.extend_from_slice(&h.to_le_bytes());
        }
        for &v in &self.col_norms {
            manifest.extend_from_slice(&v.to_le_bytes());
        }
        if let Some(y) = response {
            for &v in y {
                manifest.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = fnv1a64(&manifest);
        manifest.extend_from_slice(&sum.to_le_bytes());
        manifest.extend_from_slice(&HXD_TAIL);
        let manifest_len = manifest.len();
        self.write_bytes(&manifest)?;
        let flags = (loss_tag(self.loss) << 1)
            | if response.is_some() { FLAG_RESPONSE } else { 0 };
        self.file
            .seek(SeekFrom::Start(40))
            .and_then(|_| self.file.write_all(&flags.to_le_bytes()))
            .and_then(|_| self.file.flush())
            .map_err(|e| crate::err!("finalizing {}: {e}", self.path.display()))?;
        Ok(PackSummary {
            n: self.n,
            p: self.p,
            block_cols: self.block_cols,
            blocks: self.block_sums.len(),
            bytes: (HEADER_LEN + 8 * self.n * self.p + manifest_len) as u64,
        })
    }
}

/// Pack a resident dense design to `path`, streaming block-sized
/// panels through [`HxdWriter`].
pub fn pack_dense(
    path: &Path,
    design: &DenseMatrix,
    block_cols: usize,
    loss: Loss,
    response: Option<&[f64]>,
) -> Result<PackSummary> {
    let (n, p) = (design.nrows(), design.ncols());
    let mut w = HxdWriter::create(path, n, p, block_cols, loss)?;
    let data = design.data();
    let mut c = 0;
    while c < p {
        let e = (c + block_cols).min(p);
        w.write_cols(&data[c * n..e * n])?;
        c = e;
    }
    w.finish(response)
}

/// A [`ColumnSource`] over an `.hxd` file: buffered block reads with a
/// depth-1 block cache, FNV verification on every block served, and
/// the manifest's norms/response/loss available without touching the
/// column data.
pub struct HxdSource {
    file: File,
    path: PathBuf,
    n: usize,
    p: usize,
    block_cols: usize,
    loss: Loss,
    block_sums: Vec<u64>,
    col_norms: Vec<f64>,
    response: Option<Vec<f64>>,
    /// Depth-1 cache: (block index, decoded column values).
    cache: Option<(usize, Vec<f64>)>,
    bytes_read: u64,
    #[cfg(feature = "paranoid")]
    spot: usize,
}

impl HxdSource {
    /// Open and validate `path`: header sanity, exact file size, and
    /// the manifest hash are all checked before any column is served.
    pub fn open(path: &Path) -> Result<Self> {
        let mut file =
            File::open(path).map_err(|e| crate::err!("opening {}: {e}", path.display()))?;
        let file_len = file
            .metadata()
            .map_err(|e| crate::err!("reading metadata of {}: {e}", path.display()))?
            .len();
        if file_len < HEADER_LEN as u64 {
            return Err(crate::err!(
                "truncated .hxd file {}: {file_len} bytes is smaller than the {HEADER_LEN}-byte \
                 header",
                path.display()
            ));
        }
        let mut header = [0u8; HEADER_LEN];
        file.read_exact(&mut header)
            .map_err(|e| crate::err!("reading header of {}: {e}", path.display()))?;
        if header[..8] != HXD_MAGIC {
            return Err(crate::err!(
                "{} is not an .hxd design (bad magic {:02x?})",
                path.display(),
                &header[..8]
            ));
        }
        let version = u32_at(&header, 8);
        if version != HXD_VERSION {
            return Err(crate::err!(
                "unsupported .hxd version {version} in {} (this reader speaks version \
                 {HXD_VERSION})",
                path.display()
            ));
        }
        if u32_at(&header, 12) != ENDIAN_SENTINEL {
            return Err(crate::err!(
                "endianness sentinel mismatch in {} (written on an incompatible platform?)",
                path.display()
            ));
        }
        let n64 = u64_at(&header, 16);
        let p64 = u64_at(&header, 24);
        let bc64 = u64_at(&header, 32);
        let flags = u64_at(&header, 40);
        if flags & !KNOWN_FLAGS != 0 {
            return Err(crate::err!(
                "unknown flag bits {flags:#x} in {} (written by a newer format revision?)",
                path.display()
            ));
        }
        let loss = loss_from_tag((flags >> 1) & 0b11)?;
        let has_response = flags & FLAG_RESPONSE != 0;
        if n64 == 0 || p64 == 0 || bc64 == 0 {
            return Err(crate::err!(
                "degenerate header in {}: n = {n64}, p = {p64}, block_cols = {bc64}",
                path.display()
            ));
        }
        let data_bytes = n64
            .checked_mul(p64)
            .and_then(|v| v.checked_mul(8))
            .ok_or_else(|| {
                crate::err!(
                    "header of {} declares n = {n64}, p = {p64}: n x p overflows the 64-bit \
                     file layout",
                    path.display()
                )
            })?;
        let n = to_usize(n64, "n")?;
        let p = to_usize(p64, "p")?;
        let block_cols = to_usize(bc64, "block_cols")?;
        let nblocks = div_ceil(p, block_cols);
        let resp_len = if has_response { n as u64 } else { 0 };
        let manifest_len = 8 * (nblocks as u64 + p as u64 + resp_len) + 16;
        let expected = (HEADER_LEN as u64)
            .checked_add(data_bytes)
            .and_then(|v| v.checked_add(manifest_len))
            .ok_or_else(|| {
                crate::err!("declared size of {} overflows u64", path.display())
            })?;
        if file_len != expected {
            return Err(crate::err!(
                "truncated or oversized .hxd file {}: {file_len} bytes on disk, {expected} \
                 expected from the header ({n}x{p}, {block_cols}-column blocks)",
                path.display()
            ));
        }
        file.seek(SeekFrom::Start(HEADER_LEN as u64 + data_bytes))
            .map_err(|e| crate::err!("seeking manifest of {}: {e}", path.display()))?;
        let mut manifest = vec![0u8; to_usize(manifest_len, "manifest length")?];
        file.read_exact(&mut manifest)
            .map_err(|e| crate::err!("reading manifest of {}: {e}", path.display()))?;
        let body_len = manifest.len() - 16;
        if manifest[body_len + 8..] != HXD_TAIL {
            return Err(crate::err!(
                "missing .hxd tail marker in {} (file truncated mid-write?)",
                path.display()
            ));
        }
        let stored = u64_at(&manifest, body_len);
        let computed = fnv1a64(&manifest[..body_len]);
        if stored != computed {
            return Err(crate::err!(
                "manifest checksum mismatch in {}: stored {stored:#018x}, computed \
                 {computed:#018x} — the file is corrupt",
                path.display()
            ));
        }
        let body = &manifest[..body_len];
        let block_sums: Vec<u64> =
            body[..8 * nblocks].chunks_exact(8).map(|c| u64_at(c, 0)).collect();
        let col_norms: Vec<f64> =
            body[8 * nblocks..8 * (nblocks + p)].chunks_exact(8).map(f64_from_le).collect();
        let response = if has_response {
            Some(body[8 * (nblocks + p)..].chunks_exact(8).map(f64_from_le).collect())
        } else {
            None
        };
        Ok(Self {
            file,
            path: path.to_path_buf(),
            n,
            p,
            block_cols,
            loss,
            block_sums,
            col_norms,
            response,
            cache: None,
            bytes_read: (HEADER_LEN as u64) + manifest_len,
            #[cfg(feature = "paranoid")]
            spot: 0,
        })
    }

    /// The loss the design was packed for.
    pub fn loss(&self) -> Loss {
        self.loss
    }

    /// The packed response vector, if the file carries one.
    pub fn response(&self) -> Option<&[f64]> {
        self.response.as_deref()
    }

    /// Move the response out (the fit path owns its `y`).
    pub fn take_response(&mut self) -> Option<Vec<f64>> {
        self.response.take()
    }

    /// Checksum/cache block width.
    pub fn block_cols(&self) -> usize {
        self.block_cols
    }

    /// Load block `b` (columns `bs..be`) into the depth-1 cache,
    /// verifying its checksum against the manifest.
    fn ensure_block(&mut self, b: usize, bs: usize, be: usize) -> Result<()> {
        if matches!(&self.cache, Some((cached, _)) if *cached == b) {
            return Ok(());
        }
        let nbytes = (be - bs) * self.n * 8;
        let mut bytes = vec![0u8; nbytes];
        let off = (HEADER_LEN + bs * self.n * 8) as u64;
        self.file
            .seek(SeekFrom::Start(off))
            .and_then(|_| self.file.read_exact(&mut bytes))
            .map_err(|e| {
                crate::err!(
                    "reading block {b} (columns {bs}..{be}) of {}: {e}",
                    self.path.display()
                )
            })?;
        self.bytes_read += nbytes as u64;
        let computed = fnv1a64(&bytes);
        if computed != self.block_sums[b] {
            return Err(crate::err!(
                "checksum mismatch in block {b} (columns {bs}..{be}) of {}: stored {:#018x}, \
                 computed {computed:#018x} — the file is corrupt",
                self.path.display(),
                self.block_sums[b]
            ));
        }
        let vals: Vec<f64> = bytes.chunks_exact(8).map(f64_from_le).collect();
        self.cache = Some((b, vals));
        Ok(())
    }
}

impl ColumnSource for HxdSource {
    fn n(&self) -> usize {
        self.n
    }

    fn p(&self) -> usize {
        self.p
    }

    fn col_norms(&self) -> &[f64] {
        &self.col_norms
    }

    fn read_cols(&mut self, c0: usize, c1: usize) -> Result<Vec<f64>> {
        check_range(c0, c1, self.p)?;
        let n = self.n;
        let mut out = Vec::with_capacity((c1 - c0) * n);
        let mut c = c0;
        while c < c1 {
            let b = c / self.block_cols;
            let bs = b * self.block_cols;
            let be = (bs + self.block_cols).min(self.p);
            self.ensure_block(b, bs, be)?;
            if let Some((_, block)) = &self.cache {
                let hi = be.min(c1);
                out.extend_from_slice(&block[(c - bs) * n..(hi - bs) * n]);
                c = hi;
            }
        }
        #[cfg(feature = "paranoid")]
        if c1 > c0 {
            // Cross-check one sampled column of the served panel
            // against the manifest norm, bitwise: a wrong norm would
            // silently unsound every keep-mask built from it.
            let j = c0 + self.spot % (c1 - c0);
            self.spot = self.spot.wrapping_add(1);
            let col = &out[(j - c0) * n..(j - c0 + 1) * n];
            crate::invariants::assert_source_norm_identical(
                self.col_norms[j],
                blas::nrm2(col),
                j,
            );
        }
        Ok(out)
    }

    fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    fn source_name(&self) -> &'static str {
        "hxd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hxd-unit-{}-{tag}.hxd", std::process::id()))
    }

    fn sample(n: usize, p: usize) -> DenseMatrix {
        let data = SyntheticSpec::new(n, p, p.min(3)).seed(9).generate();
        match data.design {
            crate::data::DesignMatrix::Dense(m) => m,
            crate::data::DesignMatrix::Sparse(_) => unreachable!("dense by default"),
        }
    }

    #[test]
    fn roundtrip_is_bitwise_with_streamed_writes() {
        let (n, p) = (5, 9);
        let m = sample(n, p);
        let y: Vec<f64> = (0..n).map(|i| i as f64 - 2.5).collect();
        let path = tmp("roundtrip");
        let mut w = HxdWriter::create(&path, n, p, 4, Loss::Logistic).expect("create");
        // Uneven write granularity: 2 columns, then the remaining 7 —
        // block boundaries (4 cols) must not care.
        w.write_cols(&m.data()[..2 * n]).expect("first panel");
        w.write_cols(&m.data()[2 * n..]).expect("second panel");
        let summary = w.finish(Some(&y)).expect("finish");
        assert_eq!((summary.n, summary.p, summary.blocks), (n, p, 3));
        assert_eq!(
            summary.bytes,
            std::fs::metadata(&path).expect("metadata").len()
        );

        let mut src = HxdSource::open(&path).expect("open");
        assert_eq!((src.n(), src.p()), (n, p));
        assert_eq!(src.loss(), Loss::Logistic);
        assert_eq!(src.response().expect("response"), &y[..]);
        let full = src.read_cols(0, p).expect("full read");
        assert_eq!(full, m.data());
        // Straddle a block boundary and reread a cached block.
        let mid = src.read_cols(3, 6).expect("straddle");
        assert_eq!(mid, &m.data()[3 * n..6 * n]);
        for j in 0..p {
            let direct = blas::nrm2(m.col(j));
            assert_eq!(src.col_norms()[j].to_bits(), direct.to_bits());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn block_cache_serves_repeat_reads_without_io() {
        let (n, p) = (4, 6);
        let path = tmp("cache");
        pack_dense(&path, &sample(n, p), 8, Loss::Gaussian, None).expect("pack");
        let mut src = HxdSource::open(&path).expect("open");
        let first = src.read_cols(1, 3).expect("read");
        let after_first = src.bytes_read();
        let second = src.read_cols(1, 3).expect("cached read");
        assert_eq!(first, second);
        assert_eq!(src.bytes_read(), after_first, "cache hit must not reread the file");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_rejects_foreign_and_damaged_headers() {
        let (n, p) = (3, 5);
        let path = tmp("headers");
        pack_dense(&path, &sample(n, p), 2, Loss::Gaussian, None).expect("pack");
        let good = std::fs::read(&path).expect("read back");

        let mut bad = good.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).expect("write");
        let err = HxdSource::open(&path).expect_err("bad magic");
        assert!(err.to_string().contains("bad magic"), "got: {err}");

        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bad).expect("write");
        let err = HxdSource::open(&path).expect_err("bad version");
        assert!(err.to_string().contains("unsupported .hxd version 99"), "got: {err}");

        let mut bad = good.clone();
        bad[12] ^= 0xff;
        std::fs::write(&path, &bad).expect("write");
        let err = HxdSource::open(&path).expect_err("bad sentinel");
        assert!(err.to_string().contains("endianness sentinel"), "got: {err}");

        let mut bad = good.clone();
        bad[40] |= 0b1000;
        std::fs::write(&path, &bad).expect("write");
        let err = HxdSource::open(&path).expect_err("unknown flag");
        assert!(err.to_string().contains("unknown flag bits"), "got: {err}");

        std::fs::write(&path, &good[..good.len() - 9]).expect("truncate");
        let err = HxdSource::open(&path).expect_err("truncated");
        assert!(err.to_string().contains("truncated or oversized"), "got: {err}");

        std::fs::write(&path, &good[..20]).expect("sub-header truncate");
        let err = HxdSource::open(&path).expect_err("shorter than header");
        assert!(err.to_string().contains("smaller than the 48-byte header"), "got: {err}");

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_rejects_overflowing_shapes() {
        // A hand-built header whose n×p does not fit in u64.
        let path = tmp("overflow");
        let mut header = vec![0u8; HEADER_LEN];
        header[..8].copy_from_slice(&HXD_MAGIC);
        header[8..12].copy_from_slice(&HXD_VERSION.to_le_bytes());
        header[12..16].copy_from_slice(&ENDIAN_SENTINEL.to_le_bytes());
        header[16..24].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        header[24..32].copy_from_slice(&3u64.to_le_bytes());
        header[32..40].copy_from_slice(&64u64.to_le_bytes());
        std::fs::write(&path, &header).expect("write");
        let err = HxdSource::open(&path).expect_err("overflow");
        assert!(err.to_string().contains("overflows the 64-bit file layout"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_block_fails_on_read_not_open() {
        let (n, p) = (4, 10);
        let path = tmp("corrupt-block");
        pack_dense(&path, &sample(n, p), 3, Loss::Gaussian, None).expect("pack");
        let mut bytes = std::fs::read(&path).expect("read back");
        // Flip one byte inside block 2 (columns 6..9).
        let victim = HEADER_LEN + 6 * n * 8 + 5;
        bytes[victim] ^= 0x01;
        std::fs::write(&path, &bytes).expect("write");
        let mut src = HxdSource::open(&path).expect("manifest still intact");
        assert_eq!(src.read_cols(0, 3).expect("block 0 clean").len(), 3 * n);
        let err = src.read_cols(6, 8).expect_err("block 2 corrupt");
        let msg = err.to_string();
        assert!(msg.contains("checksum mismatch in block 2"), "got: {msg}");
        assert!(msg.contains("corrupt"), "got: {msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_manifest_fails_at_open() {
        let (n, p) = (3, 4);
        let path = tmp("corrupt-manifest");
        pack_dense(&path, &sample(n, p), 2, Loss::Gaussian, None).expect("pack");
        let mut bytes = std::fs::read(&path).expect("read back");
        // Flip a norm byte (inside the manifest, after the block sums).
        let norms_off = HEADER_LEN + n * p * 8 + 2 * 8;
        bytes[norms_off] ^= 0x10;
        std::fs::write(&path, &bytes).expect("write");
        let err = HxdSource::open(&path).expect_err("manifest corrupt");
        assert!(err.to_string().contains("manifest checksum mismatch"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn writer_misuse_is_rejected() {
        let path = tmp("misuse");
        let err = HxdWriter::create(&path, 3, 4, 0, Loss::Gaussian).expect_err("zero block");
        assert!(err.to_string().contains("at least 1 column"), "got: {err}");
        let err = HxdWriter::create(&path, 0, 4, 2, Loss::Gaussian).expect_err("empty");
        assert!(err.to_string().contains("empty design"), "got: {err}");

        let m = sample(3, 4);
        let mut w = HxdWriter::create(&path, 3, 4, 2, Loss::Gaussian).expect("create");
        let err = w.write_cols(&m.data()[..4]).expect_err("ragged panel");
        assert!(err.to_string().contains("whole number"), "got: {err}");
        w.write_cols(&m.data()[..2 * 3]).expect("two columns");
        let err = w.finish(None).expect_err("early finish");
        assert!(err.to_string().contains("packed only 2 of 4"), "got: {err}");

        let mut w = HxdWriter::create(&path, 3, 4, 2, Loss::Gaussian).expect("recreate");
        w.write_cols(m.data()).expect("all columns");
        let err = w.write_cols(&m.data()[..3]).expect_err("past p");
        assert!(err.to_string().contains("exceed p = 4"), "got: {err}");

        let mut w = HxdWriter::create(&path, 3, 4, 2, Loss::Gaussian).expect("recreate");
        w.write_cols(m.data()).expect("all columns");
        let err = w.finish(Some(&[1.0, 2.0])).expect_err("short response");
        assert!(err.to_string().contains("expected n = 3"), "got: {err}");

        let _ = std::fs::remove_file(&path);
    }
}
