//! Runtime invariant checks behind the non-default `paranoid` cargo
//! feature (`make test-paranoid`, CI job `paranoid`).
//!
//! Screening is only as trustworthy as its invariants: a silently
//! dropped *active* predictor corrupts every later path step with no
//! error anywhere (the motivation for hybrid rules pairing heuristic
//! screens with exact KKT checks — §3.2/§3.3.4). The checks here are
//! oracles for the contracts the optimized code paths rely on:
//!
//! * [`assert_gram_symmetric`] — H must be *exactly* symmetric after
//!   triangle mirroring (float multiplication is not associative, so
//!   an un-mirrored panel differs in the last bit and Cholesky drifts).
//! * [`assert_screened_sound`] — at an accepted step, every discarded
//!   predictor must satisfy the Gap-Safe ball bound
//!   `|xⱼᵀr| ≤ λ + ‖xⱼ‖·√(2·gap) + slack`: the dual optimum lies
//!   within `√(2·gap)/λ` of the current dual point, so a correctly
//!   discarded predictor cannot exceed this — and a wrongly discarded
//!   active one shows up as a violation far beyond the slack.
//! * [`assert_upload_stats_sane`] — the shard pipeline's counters obey
//!   `overlapped ≤ uploaded ≤ staged ≤ uploaded + 2` (double
//!   buffering: at most one panel in the channel plus one just staged)
//!   and the byte gauges obey `inflight ≤ peak ≤ 2·max_panel` — the
//!   out-of-core memory bound the streaming path promises.
//! * [`assert_staged_panel_bounded`] — a staged panel is never larger
//!   than one shard (`n·chunk` values): the streaming path must not
//!   quietly materialize a full `n×p` buffer.
//! * [`assert_source_norm_identical`] — a column norm read from an
//!   `.hxd` manifest is bit-identical to a recompute from the column
//!   bytes just decoded (a mismatch means pack/read disagree).
//! * [`assert_spot_identical`] — sharded reductions are bit-identical
//!   to a serial recompute; checked on sampled columns in
//!   `ShardedBackend::correlation`.
//!
//! Every check panics with enough context to reproduce; they are
//! asserts, not `Result`s, because a violated invariant means the
//! process is already computing garbage.

use crate::linalg::DenseMatrix;
use crate::runtime::UploadStats;

/// Exact (bitwise) symmetry of a mirrored Gram/Hessian panel.
pub fn assert_gram_symmetric(h: &DenseMatrix, context: &str) {
    assert_eq!(h.nrows(), h.ncols(), "{context}: H must be square");
    let k = h.nrows();
    for a in 0..k {
        for b in 0..a {
            let ab = h.at(a, b);
            let ba = h.at(b, a);
            assert!(
                ab.to_bits() == ba.to_bits(),
                "{context}: H[{a},{b}]={ab:e} != H[{b},{a}]={ba:e} — triangle mirroring broken"
            );
        }
    }
}

/// Screened-set soundness at an accepted path step.
///
/// `c` is a *freshly recomputed* full correlation vector at the
/// accepted iterate, `kept[j]` says predictor `j` was in the working
/// set (never screened out this step), `gap` the duality gap at the
/// same iterate. For discarded `j` the Gap-Safe ball argument bounds
/// `|c[j]| ≤ λ + ‖xⱼ‖·√(2·gap)`; the relative slack absorbs float
/// round-off only — a real screening bug lands far outside it.
pub fn assert_screened_sound(c: &[f64], col_norms: &[f64], kept: &[bool], lambda: f64, gap: f64) {
    assert_eq!(c.len(), kept.len(), "mask length mismatch");
    assert_eq!(c.len(), col_norms.len(), "norm length mismatch");
    let radius = (2.0 * gap.max(0.0)).sqrt();
    let slack = 1e-8 * lambda.abs().max(1.0) + 1e-12;
    for (j, &cj) in c.iter().enumerate() {
        if kept[j] {
            continue;
        }
        let bound = lambda + col_norms[j] * radius + slack;
        assert!(
            cj.abs() <= bound,
            "screened-set soundness violated: discarded predictor {j} has |c|={:e} > \
             λ + ‖xⱼ‖·√(2·gap) + slack = {bound:e} (λ={lambda:e}, gap={gap:e}) — \
             an active predictor was screened out",
            cj.abs()
        );
    }
}

/// Shard-upload pipeline counter balance. Holds at any instant for a
/// single in-flight pipeline (the only usage pattern): the stager can
/// lead the uploader by at most one panel in the `sync_channel(1)`
/// plus one staged-but-unsent panel; at quiescence `staged ==
/// uploaded` exactly (asserted by the pipeline tests).
pub fn assert_upload_stats_sane(stats: &UploadStats) {
    assert!(
        stats.overlapped <= stats.uploaded,
        "overlapped {} > uploaded {} — an overlap was counted without its upload",
        stats.overlapped,
        stats.uploaded
    );
    assert!(
        stats.uploaded <= stats.staged,
        "uploaded {} > staged {} — a panel was uploaded that was never staged",
        stats.uploaded,
        stats.staged
    );
    assert!(
        stats.staged - stats.uploaded <= 2,
        "staged {} leads uploaded {} by more than the double-buffer depth",
        stats.staged,
        stats.uploaded
    );
    for (name, v) in [
        ("stage_seconds", stats.stage_seconds),
        ("upload_seconds", stats.upload_seconds),
        ("stall_seconds", stats.stall_seconds),
        ("read_seconds", stats.read_seconds),
    ] {
        assert!(v.is_finite() && v >= 0.0, "{name} is {v}");
    }
    assert!(
        stats.inflight_bytes <= stats.peak_inflight_bytes,
        "inflight_bytes {} > peak_inflight_bytes {} — the peak gauge missed an update",
        stats.inflight_bytes,
        stats.peak_inflight_bytes
    );
    assert!(
        stats.peak_inflight_bytes <= 2 * stats.max_panel_bytes,
        "peak_inflight_bytes {} > 2·max_panel_bytes = {} — more than two shard panels \
         were resident at once; the double-buffer memory bound is broken",
        stats.peak_inflight_bytes,
        2 * stats.max_panel_bytes
    );
}

/// A staged panel must be at most one shard wide: `len == n·width` and
/// `width ≤ chunk`. Violations mean the streaming path materialized
/// more than a shard in one read — the exact failure mode out-of-core
/// registration exists to prevent.
pub fn assert_staged_panel_bounded(panel_len: usize, n: usize, width: usize, chunk: usize) {
    assert!(
        panel_len == n * width,
        "staged panel holds {panel_len} values, expected n·width = {n}·{width} = {}",
        n * width
    );
    assert!(
        width <= chunk,
        "staged panel spans {width} columns > shard chunk {chunk} — \
         the stager read past its shard"
    );
}

/// Bitwise equality of a manifest column norm against a recompute from
/// the decoded column bytes. Spot-checked on sampled columns in
/// `HxdSource::read_cols`.
pub fn assert_source_norm_identical(manifest: f64, recomputed: f64, col: usize) {
    assert!(
        manifest.to_bits() == recomputed.to_bits(),
        "column {col} norm mismatch: manifest {manifest:e} (bits {:#x}) != \
         recomputed {recomputed:e} (bits {:#x}) — pack and read disagree on the bytes",
        manifest.to_bits(),
        recomputed.to_bits()
    );
}

/// Bitwise equality of a sharded reduction entry against a serial
/// recompute of the same column.
pub fn assert_spot_identical(merged: f64, serial: f64, col: usize) {
    assert!(
        merged.to_bits() == serial.to_bits(),
        "shard reduction mismatch at column {col}: merged {merged:e} (bits {:#x}) != \
         serial {serial:e} (bits {:#x}) — shard offsets or concatenation order broken",
        merged.to_bits(),
        serial.to_bits()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym2(a: f64, b: f64, c: f64) -> DenseMatrix {
        let mut h = DenseMatrix::zeros(2, 2);
        *h.at_mut(0, 0) = a;
        *h.at_mut(0, 1) = b;
        *h.at_mut(1, 0) = b;
        *h.at_mut(1, 1) = c;
        h
    }

    #[test]
    fn symmetric_panel_passes() {
        assert_gram_symmetric(&sym2(2.0, 0.5, 3.0), "test");
        assert_gram_symmetric(&DenseMatrix::zeros(0, 0), "empty");
    }

    #[test]
    #[should_panic(expected = "triangle mirroring broken")]
    fn last_bit_asymmetry_is_caught() {
        let mut h = sym2(2.0, 0.5, 3.0);
        // One ulp of drift — exactly what an un-mirrored dot_w pair
        // produces — must already fail.
        *h.at_mut(1, 0) = f64::from_bits(0.5f64.to_bits() + 1);
        assert_gram_symmetric(&h, "test");
    }

    #[test]
    fn sound_screens_pass_including_gap_slack() {
        // Discarded predictor slightly above λ but inside the ball
        // radius: legitimate at a finite-tolerance iterate.
        let lambda = 1.0;
        let gap = 1e-6;
        let c = [1.3, 1.0005, 0.2];
        let norms = [1.0, 1.0, 1.0];
        let kept = [true, false, false];
        assert_screened_sound(&c, &norms, &kept, lambda, gap);
    }

    #[test]
    #[should_panic(expected = "screened-set soundness violated")]
    fn dropped_active_predictor_is_caught() {
        let c = [1.3, 0.2];
        let norms = [1.0, 1.0];
        let kept = [false, true]; // |c0| = 1.3 >> λ + ‖x‖·√(2·gap)
        assert_screened_sound(&c, &norms, &kept, 1.0, 1e-10);
    }

    #[test]
    fn balanced_stats_pass() {
        let s = UploadStats {
            staged: 5,
            uploaded: 4,
            overlapped: 2,
            stage_seconds: 0.1,
            upload_seconds: 0.2,
            stall_seconds: 0.0,
            bytes_read: 4096,
            read_seconds: 0.05,
            inflight_bytes: 512,
            peak_inflight_bytes: 1024,
            max_panel_bytes: 512,
        };
        assert_upload_stats_sane(&s);
        assert_upload_stats_sane(&UploadStats::default());
    }

    #[test]
    #[should_panic(expected = "memory bound is broken")]
    fn triple_buffering_is_caught() {
        assert_upload_stats_sane(&UploadStats {
            peak_inflight_bytes: 1537,
            max_panel_bytes: 512,
            ..UploadStats::default()
        });
    }

    #[test]
    fn bounded_panels_pass() {
        assert_staged_panel_bounded(60, 20, 3, 5);
        assert_staged_panel_bounded(0, 20, 0, 5); // empty shard
    }

    #[test]
    #[should_panic(expected = "read past its shard")]
    fn overwide_panel_is_caught() {
        assert_staged_panel_bounded(120, 20, 6, 5);
    }

    #[test]
    #[should_panic(expected = "expected n·width")]
    fn short_panel_is_caught() {
        assert_staged_panel_bounded(59, 20, 3, 5);
    }

    #[test]
    fn matching_norms_pass() {
        assert_source_norm_identical(0.1 + 0.2, 0.1 + 0.2, 7);
    }

    #[test]
    #[should_panic(expected = "pack and read disagree")]
    fn one_ulp_norm_drift_is_caught() {
        let v = 0.1 + 0.2;
        assert_source_norm_identical(v, f64::from_bits(v.to_bits() + 1), 7);
    }

    #[test]
    #[should_panic(expected = "never staged")]
    fn upload_without_stage_is_caught() {
        assert_upload_stats_sane(&UploadStats {
            staged: 1,
            uploaded: 2,
            ..UploadStats::default()
        });
    }

    #[test]
    #[should_panic(expected = "double-buffer depth")]
    fn runaway_stager_is_caught() {
        assert_upload_stats_sane(&UploadStats {
            staged: 7,
            uploaded: 3,
            ..UploadStats::default()
        });
    }

    #[test]
    fn spot_identical_is_bitwise() {
        assert_spot_identical(0.1 + 0.2, 0.1 + 0.2, 3);
    }

    #[test]
    #[should_panic(expected = "shard reduction mismatch")]
    fn one_ulp_reduction_drift_is_caught() {
        let v = 0.1 + 0.2;
        assert_spot_identical(v, f64::from_bits(v.to_bits() + 1), 3);
    }
}
