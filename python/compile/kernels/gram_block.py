"""Layer-1 Pallas kernel: weighted Gram panel G = X_Eᵀ D(w) X_D.

This is the augmentation-step workload of the paper's Algorithm 1: when
predictors D enter the active set, the sweep update needs the panels
X_EᵀX_D and X_DᵀX_D (weighted by D(w) for GLM losses) — the O(n·|D|·|E|)
term that §3.3.1 identifies as the dominant cost of maintaining the
Hessian. The kernel streams the sample dimension in TN-wide slices and
accumulates the (e, d) panel in VMEM; e and d are the active-set block
sizes (tens to a few hundred), so the output block always fits.

VMEM per grid step: TN·(e + d + 1)·4 bytes + e·d·4 for the accumulator —
with e = d = 128, TN = 512 that is ~585 KiB.

Lowered with ``interpret=True`` (see xt_r.py for why).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xe_ref, w_ref, xd_ref, o_ref):
    i_n = pl.program_id(0)

    @pl.when(i_n == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (e, TN) @ (TN, d) with the weight slice fused into the right panel.
    wslice = w_ref[...]  # (TN, 1)
    o_ref[...] += jnp.dot(
        xe_ref[...], wslice * xd_ref[...].T, preferred_element_type=o_ref.dtype
    )


def _pick_tile(dim: int, target: int) -> int:
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tn",))
def gram_block(
    xe_t: jnp.ndarray, w: jnp.ndarray, xd_t: jnp.ndarray, tn: int = 512
) -> jnp.ndarray:
    """G = X_Eᵀ D(w) X_D.

    ``xe_t``: (e, n); ``w``: (n, 1); ``xd_t``: (d, n). Returns (e, d).
    """
    e, n = xe_t.shape
    d, n2 = xd_t.shape
    assert n == n2, f"sample dims differ: {n} vs {n2}"
    assert w.shape == (n, 1), f"w must be (n,1), got {w.shape}"
    tn = _pick_tile(n, tn)
    grid = (n // tn,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((e, tn), lambda i_n: (0, i_n)),
            pl.BlockSpec((tn, 1), lambda i_n: (i_n, 0)),
            pl.BlockSpec((d, tn), lambda i_n: (0, i_n)),
        ],
        out_specs=pl.BlockSpec((e, d), lambda i_n: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d), xe_t.dtype),
        interpret=True,
    )(xe_t, w, xd_t)


def vmem_bytes(e: int, d: int, tn: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM working-set estimate (module docstring)."""
    return dtype_bytes * (tn * (e + d + 1) + e * d)
