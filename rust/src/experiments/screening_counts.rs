//! Figure 1 / Figure 7 / Table 3: screening effectiveness and
//! violations.
//!
//! Fits full paths on the appendix design (n=200, p=20 000 at `--full`)
//! for ρ ∈ {0, 0.4, 0.8} with the Hessian, Strong and EDPP rules
//! (ℓ₁-least-squares) and Hessian/Strong (logistic), recording the
//! average screened-set size and the average number of violations per
//! path — the content of Fig. 1/7 (series) and Table 3 (averages).

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

struct Cell {
    loss: Loss,
    rho: f64,
    kind: ScreeningKind,
    rep: u64,
}

fn methods_for(loss: Loss) -> Vec<ScreeningKind> {
    match loss {
        Loss::Gaussian => vec![
            ScreeningKind::Hessian,
            ScreeningKind::Strong,
            ScreeningKind::Edpp,
        ],
        _ => vec![ScreeningKind::Hessian, ScreeningKind::Strong],
    }
}

fn run_grid(cfg: &ExpConfig) -> (Table, String) {
    let (n, p, s) = cfg.appendix_dim();
    let mut cells = Vec::new();
    for loss in [Loss::Gaussian, Loss::Logistic] {
        for &rho in &[0.0, 0.4, 0.8] {
            for kind in methods_for(loss) {
                for rep in 0..cfg.reps as u64 {
                    cells.push(Cell {
                        loss,
                        rho,
                        kind,
                        rep,
                    });
                }
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig1/tab3", cells, |i, c| {
        let snr = 2.0;
        let data = simulate(n, p, s, c.rho, snr, c.loss, cfg.cell_seed(i as u64, c.rep));
        let (fit, _) = fit_timed(&data, c.kind, &paper_settings());
        let steps = fit.steps.len().max(1) as f64;
        let screened = fit.steps.iter().map(|s| s.screened as f64).sum::<f64>() / steps;
        let violations = fit.total_violations() as f64 / steps;
        let min_active = fit.steps.iter().map(|s| s.active as f64).sum::<f64>() / steps;
        // per-step series for the figure
        let series: Vec<String> = fit
            .steps
            .iter()
            .enumerate()
            .map(|(k, s)| {
                format!(
                    "{:?},{},{},{},{},{},{}",
                    c.loss, c.rho, c.kind, k, s.screened, s.active, s.violations
                )
            })
            .collect();
        ((c.loss, c.rho, c.kind), screened, violations, min_active, series)
    });

    // Aggregate per (loss, rho, kind).
    let mut table = Table::new(&[
        "Model", "rho", "Method", "Screened", "Active", "Violations",
    ]);
    let mut series_csv = String::from("loss,rho,method,step,screened,active,violations\n");
    for loss in [Loss::Gaussian, Loss::Logistic] {
        for &rho in &[0.0, 0.4, 0.8] {
            for kind in methods_for(loss) {
                let rows: Vec<_> = results
                    .iter()
                    .filter(|(c, ..)| c.0 == loss && c.1 == rho && c.2 == kind)
                    .collect();
                let scr = Summary::of(&rows.iter().map(|r| r.1).collect::<Vec<_>>());
                let vio = Summary::of(&rows.iter().map(|r| r.2).collect::<Vec<_>>());
                let act = Summary::of(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
                table.row(vec![
                    format!("{loss:?}"),
                    format!("{rho}"),
                    kind.name().into(),
                    format!("{}", sig_figs(scr.mean, 4)),
                    format!("{}", sig_figs(act.mean, 4)),
                    format!("{}", sig_figs(vio.mean, 2)),
                ]);
                if let Some((_, _, _, _, series)) = rows.first() {
                    for line in series {
                        series_csv.push_str(line);
                        series_csv.push('\n');
                    }
                }
            }
        }
    }
    (table, series_csv)
}

/// Figure 1 / Figure 7: screened counts (prints + CSV series).
pub fn run_counts(cfg: &ExpConfig) -> Result<(), String> {
    let (table, series) = run_grid(cfg);
    println!("\nFigure 1 / Figure 7 — average screened predictors per step");
    println!("{}", table.render());
    write_csv(cfg, "fig1_screened", &table);
    write_text(cfg, "fig1_series.csv", &series);
    Ok(())
}

/// Table 3: screened + violations averages (same grid, table focus).
pub fn run_violations(cfg: &ExpConfig) -> Result<(), String> {
    let (table, _) = run_grid(cfg);
    println!("\nTable 3 — screened predictors and violations");
    println!("{}", table.render());
    write_csv(cfg, "tab3_violations", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_and_orders_methods() {
        // Miniature version of the experiment: the Hessian rule must
        // screen fewer predictors than Strong at high correlation — the
        // paper's headline qualitative claim (Fig. 1).
        let data = simulate(60, 600, 5, 0.8, 2.0, Loss::Gaussian, 7);
        let (h, _) = fit_timed(&data, ScreeningKind::Hessian, &paper_settings());
        let (s, _) = fit_timed(&data, ScreeningKind::Strong, &paper_settings());
        let (e, _) = fit_timed(&data, ScreeningKind::Edpp, &paper_settings());
        assert!(h.mean_screened() < s.mean_screened());
        // EDPP is known-conservative (Table 3: thousands screened).
        assert!(s.mean_screened() < e.mean_screened());
    }

    #[test]
    fn violations_rare_for_strong_rule() {
        let data = simulate(60, 400, 5, 0.4, 2.0, Loss::Gaussian, 8);
        let (s, _) = fit_timed(&data, ScreeningKind::Strong, &paper_settings());
        assert!(s.total_violations() <= 1, "strong violations {}", s.total_violations());
    }
}
