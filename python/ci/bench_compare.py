#!/usr/bin/env python3
"""Perf-trajectory gate: diff a fresh BENCH_sweeps.json against the
committed baseline.

Records are keyed on (name, backend, threads, shards, batch, design)
— the same identity the bench writes — and compared on mean
wall-seconds:

  ratio = fresh / baseline
  ratio > --warn  (default 1.25x)  ->  warning, exit 0
  ratio > --fail  (default 1.50x)  ->  regression, exit 1

Entries faster than --min-seconds in the *baseline* never gate: at
micro-second scale, shared-runner jitter swamps any real signal.
Keys present on only one side are reported but never gate — they are
a coverage change, not a regression.

The gate is advisory in CI (the perf job is continue-on-error): it
puts the verdict in the log and the trajectory in the artifact without
blocking merges on noisy runners. Baseline refresh ritual: `make
bench-baseline` on a quiet machine (refuses on dirty bench sources),
or download a trusted CI run's BENCH_sweeps-t* artifact, then commit
it as BENCH_baseline.json (see README "Perf trajectory").

Exit codes: 0 ok/warn-only, 1 fail-level regression, 2 usage/IO error.

stdlib only — runs on a bare python3, no pip installs.
"""

from __future__ import annotations

import argparse
import json
import sys


def _die(msg):
    """Usage/IO error: message on stderr, exit 2 (1 is reserved for a
    real fail-level regression)."""
    print(msg, file=sys.stderr)
    raise SystemExit(2)


def load_records(path):
    """Read a bench JSON file into {key: record}. Duplicate keys keep
    the last record (the bench never emits duplicates; a hand-edited
    baseline might)."""
    try:
        with open(path) as fh:
            records = json.load(fh)
    except OSError as e:
        _die(f"bench_compare: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        _die(f"bench_compare: {path} is not valid JSON: {e}")
    if not isinstance(records, list):
        _die(f"bench_compare: {path}: expected a JSON array of records")
    out = {}
    for r in records:
        try:
            key = (
                r["name"],
                r["backend"],
                int(r["threads"]),
                # Baselines predating the sharded backend have no
                # shards field: those records are unsharded.
                int(r.get("shards", 1)),
                int(r["batch"]),
                # Baselines predating out-of-core storage have no
                # design field: those records ran on resident buffers.
                str(r.get("design", "resident")),
            )
            out[key] = {"wall_seconds": float(r["wall_seconds"])}
        except (KeyError, TypeError, ValueError) as e:
            _die(f"bench_compare: {path}: malformed record {r!r}: {e}")
    return out


def fmt_key(key):
    name, backend, threads, shards, batch, design = key
    return f"{name} [{backend} t={threads} s={shards} B={batch} d={design}]"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly produced BENCH_sweeps.json")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument(
        "--warn", type=float, default=1.25, help="warn ratio (default 1.25)"
    )
    ap.add_argument(
        "--fail", type=float, default=1.5, help="fail ratio (default 1.5)"
    )
    ap.add_argument(
        "--min-seconds",
        type=float,
        default=1e-4,
        help="baseline entries faster than this never gate (noise floor)",
    )
    args = ap.parse_args(argv)
    if args.fail < args.warn:
        ap.error("--fail must be >= --warn")

    fresh = load_records(args.fresh)
    baseline = load_records(args.baseline)

    worst = 0.0
    warns, fails = [], []
    compared = 0
    for key in sorted(baseline):
        if key not in fresh:
            print(f"  missing in fresh run (not gated): {fmt_key(key)}")
            continue
        base_s = baseline[key]["wall_seconds"]
        fresh_s = fresh[key]["wall_seconds"]
        if base_s < args.min_seconds:
            print(
                f"  below noise floor ({base_s:.2e}s < {args.min_seconds:.0e}s), "
                f"not gated: {fmt_key(key)}"
            )
            continue
        compared += 1
        ratio = fresh_s / base_s if base_s > 0 else float("inf")
        worst = max(worst, ratio)
        line = f"  {ratio:5.2f}x  {fresh_s:.3e}s vs {base_s:.3e}s  {fmt_key(key)}"
        if ratio > args.fail:
            fails.append(line)
        elif ratio > args.warn:
            warns.append(line)
        else:
            print(f"ok{line}")
    for key in sorted(set(fresh) - set(baseline)):
        print(f"  new since baseline (not gated): {fmt_key(key)}")

    if warns:
        print(f"\nWARN: {len(warns)} record(s) above {args.warn}x:")
        for line in warns:
            print(line)
    if fails:
        print(f"\nFAIL: {len(fails)} record(s) above {args.fail}x:")
        for line in fails:
            print(line)
        print(
            "\nIf this is expected (new hardware, intentional trade-off), refresh "
            "the baseline from a trusted CI artifact — see README 'Perf trajectory'."
        )
        return 1
    print(
        f"\nperf-gate: {compared} record(s) compared, worst ratio "
        f"{worst:.2f}x (warn {args.warn}x, fail {args.fail}x)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
