"""AOT path tests: lowering to HLO text must succeed and produce
modules the xla-crate side can parse (structural checks here; the
rust integration test executes them for real numerics)."""

import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (advisory oracle suite)")

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_produces_hlo_module():
    lowered = jax.jit(model.correlation).lower(
        aot.spec((16, 8)), aot.spec((8, 1))
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # return_tuple=True: the root is a tuple
    assert "tuple" in text


def test_build_artifacts_writes_manifest(tmp_path):
    # Shrink the shape lists for test speed.
    old_sweep, old_panel = aot.SWEEP_SHAPES, aot.PANEL_SHAPES
    aot.SWEEP_SHAPES, aot.PANEL_SHAPES = [(8, 16)], [(4, 2, 8)]
    try:
        rows = aot.build_artifacts(str(tmp_path))
    finally:
        aot.SWEEP_SHAPES, aot.PANEL_SHAPES = old_sweep, old_panel
    assert len(rows) == 4  # xt_r + lasso_kkt + logistic_kkt + gram_block
    manifest = os.path.join(str(tmp_path), "manifest.tsv")
    assert os.path.exists(manifest)
    with open(manifest) as f:
        lines = [l.strip().split("\t") for l in f if l.strip()]
    assert len(lines) == 4
    for op, key, dtype, fname in lines:
        assert dtype == "f32"
        path = os.path.join(str(tmp_path), fname)
        assert os.path.exists(path), fname
        with open(path) as g:
            assert g.read(9) == "HloModule"


def test_lowered_kkt_numerics_vs_model(tmp_path):
    # Compile the lowered module back with jax and compare to the eager
    # model — guards against lowering-time shape/layout mistakes.
    p, n = 12, 10
    lowered = jax.jit(model.lasso_kkt).lower(
        aot.spec((p, n)), aot.spec((n, 1)), aot.spec((n, 1)), aot.spec(())
    )
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    xt = jnp.asarray(rng.standard_normal((p, n)), dtype=jnp.float32)
    y = jnp.asarray(rng.standard_normal((n, 1)), dtype=jnp.float32)
    eta = jnp.asarray(rng.standard_normal((n, 1)), dtype=jnp.float32)
    lam = jnp.float32(0.3)
    got = compiled(xt, y, eta, lam)
    want = model.lasso_kkt(xt, y, eta, lam)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("op", ["xt_r", "lasso_kkt", "logistic_kkt", "gram_block"])
def test_manifest_ops_cover_runtime_registry(op):
    # The rust registry dispatches on these exact op names; keep the
    # contract explicit so a rename breaks loudly here.
    known = {"xt_r", "lasso_kkt", "logistic_kkt", "gram_block"}
    assert op in known
