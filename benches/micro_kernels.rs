//! Bench: micro-kernels on the L3 hot path — dot, axpy, a
//! coordinate-descent epoch, the Algorithm-1 panel update (scalar vs.
//! engine-routed) — plus the sweep suite: the full correlation sweep
//! and fused/batched KKT sweeps through the runtime backend at 1 and T
//! threads. This is the §Perf instrumentation (EXPERIMENTS.md).
//!
//! Flags (after `--`):
//!   --quick            tiny shape for CI smoke runs (200 x 4000)
//!   --n N --p P        sweep-suite shape override (default 400 x 40000)
//!   --threads T        threaded-kernel worker count (0 = all cores)
//!   --shards S         also bench the column-sharded backend at S
//!                      shards (pipelined uploads; 0/absent = skip)
//!   --design           also bench the out-of-core path: pack the
//!                      design to a temp .hxd and time the streamed,
//!                      checksum-verified registration (bytes/s)
//!   --reps R           timed repetitions per kernel
//!   --json OUT         write the sweep-suite records to OUT
//!                      (machine-readable perf trajectory — see
//!                      BENCH_sweeps.json at the repo root)

use hessian_screening::cli::Args;
use hessian_screening::cv::{cross_validate_with_engine, CvSettings};
use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::hessian::HessianTracker;
use hessian_screening::linalg::{blas, Design};
use hessian_screening::loss::Loss;
use hessian_screening::metrics::Summary;
use hessian_screening::rng::Xoshiro256pp;
use hessian_screening::runtime::{EngineSweep, RuntimeEngine};
use hessian_screening::screening::ScreeningKind;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> Summary {
    // warmup
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let s = Summary::of(&times);
    println!(
        "{name:<52} {:>12.3} µs  ± {:>8.3}",
        s.mean * 1e6,
        s.ci_half * 1e6
    );
    s
}

/// One machine-readable sweep-suite record.
struct Record {
    name: &'static str,
    n: usize,
    p: usize,
    backend: &'static str,
    threads: usize,
    /// Column shards the backend splits the design into (1 = unsharded).
    shards: usize,
    batch: usize,
    /// Where the design bytes live during registration: "resident"
    /// (host buffer) or "hxd" (streamed from a packed .hxd file).
    design: &'static str,
    wall_seconds: f64,
    ci_half: f64,
}

fn write_json(path: &str, records: &[Record]) {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"n\": {}, \"p\": {}, \"backend\": \"{}\", \
             \"threads\": {}, \"shards\": {}, \"batch\": {}, \"design\": \"{}\", \
             \"wall_seconds\": {:.9}, \"ci_half\": {:.9}}}{}\n",
            r.name,
            r.n,
            r.p,
            r.backend,
            r.threads,
            r.shards,
            r.batch,
            r.design,
            r.wall_seconds,
            r.ci_half,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {} sweep records to {path}", records.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Typed flag lookup that refuses to run on a malformed value — a
/// silently-defaulted typo would poison the recorded perf trajectory.
fn usize_flag(args: &Args, key: &str) -> Option<usize> {
    match args.get_usize(key) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.flag("quick");
    // The quick shape still clears the native backend's parallel
    // cutoff so the threaded records are real.
    let n = usize_flag(&args, "n").unwrap_or(if quick { 200 } else { 400 });
    let p = usize_flag(&args, "p").unwrap_or(if quick { 4_000 } else { 40_000 });
    let reps = usize_flag(&args, "reps").unwrap_or(if quick { 5 } else { 15 });
    let threads = usize_flag(&args, "threads").unwrap_or(0);
    let shards = usize_flag(&args, "shards").unwrap_or(0);

    let data = SyntheticSpec::new(n, p, 20).rho(0.4).seed(1).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let y = data.response.clone();
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let mut v = vec![0.0; n];
    rng.fill_gaussian(&mut v);

    println!("micro-kernels (n={n}, p={p})");
    let col = dense.col(17).to_vec();
    let mut acc = 0.0;
    bench("blas::dot", 2_000, || {
        acc += blas::dot(&col, std::hint::black_box(&v));
    });
    let mut out = vec![0.0; n];
    bench("blas::axpy", 2_000, || {
        blas::axpy(1.0001, &col, &mut out);
        std::hint::black_box(&out);
    });

    // CD epoch over a 100-predictor working set.
    let working: Vec<usize> = (0..100.min(p)).collect();
    let mut beta = vec![0.0; p];
    let mut resid = y.clone();
    let norms: Vec<f64> = working.iter().map(|&j| dense.col_sq_norm(j)).collect();
    bench("CD epoch (|W|=100)", 200, || {
        for (k, &j) in working.iter().enumerate() {
            let g = dense.col_dot(j, &resid);
            let u = g + norms[k] * beta[j];
            let new = blas::soft_threshold(u, 50.0) / norms[k];
            if new != beta[j] {
                dense.col_axpy(j, beta[j] - new, &mut resid);
                beta[j] = new;
            }
        }
        std::hint::black_box(&resid);
    });

    let mut records: Vec<Record> = Vec::new();

    // ------------- blocked-kernel suite (JSON-recorded) -------------
    // Register-blocked panel dot vs. the scalar per-column loop over
    // the same columns. The accumulation order is identical by
    // construction (asserted below, bitwise), so the delta is pure
    // memory traffic: one pass over the streamed vector per
    // PANEL_BLOCK columns instead of one per column.
    let kb = 256.min(p);
    let panel = &dense.data()[..kb * n];
    let mut out_block = vec![0.0; kb];
    let s = bench(
        &format!("blas::dot_panel ({kb} cols, B={})", blas::PANEL_BLOCK),
        reps,
        || {
            blas::dot_panel(panel, n, std::hint::black_box(&v), &mut out_block);
            std::hint::black_box(&out_block);
        },
    );
    records.push(Record {
        name: "dot_panel",
        n,
        p: kb,
        backend: "native",
        threads: 1,
        shards: 1,
        batch: blas::PANEL_BLOCK,
        design: "resident",
        wall_seconds: s.mean,
        ci_half: s.ci_half,
    });
    let mut out_scalar = vec![0.0; kb];
    let s = bench(&format!("scalar dot loop ({kb} cols)"), reps, || {
        for (j, o) in out_scalar.iter_mut().enumerate() {
            *o = blas::dot(&panel[j * n..(j + 1) * n], std::hint::black_box(&v));
        }
        std::hint::black_box(&out_scalar);
    });
    records.push(Record {
        name: "dot_cols_scalar",
        n,
        p: kb,
        backend: "native",
        threads: 1,
        shards: 1,
        batch: 1,
        design: "resident",
        wall_seconds: s.mean,
        ci_half: s.ci_half,
    });
    assert_eq!(
        out_block, out_scalar,
        "blocked panel dot must be bitwise-identical to the scalar loop"
    );

    // ---------------- sweep suite (JSON-recorded) ----------------
    // The threaded engine at 1 thread is the sequential baseline; the
    // per-column kernels are identical, so any delta is pure
    // parallelism, not numerics.
    let eta = vec![0.0; n];
    let lookahead = 4usize;
    let mut thread_counts = vec![1usize];
    let t_engine = RuntimeEngine::native_threaded(threads);
    if t_engine.threads() > 1 {
        thread_counts.push(t_engine.threads());
    }
    println!("\nsweep suite (n={n}, p={p}, backends at threads {thread_counts:?})");
    let mut per_thread_mean = Vec::new();
    for &t in &thread_counts {
        let engine = RuntimeEngine::native_threaded(t);
        let reg = engine.register_design(dense.data(), n, p).unwrap();

        let s = bench(&format!("correlation X^T r (threads={t})"), reps, || {
            let _ = std::hint::black_box(engine.correlation(&reg, &v).unwrap());
        });
        records.push(Record {
            name: "correlation",
            n,
            p,
            backend: engine.backend_name(),
            threads: t,
            shards: 1,
            batch: 1,
            design: "resident",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });

        let s = bench(&format!("fused kkt_sweep (threads={t})"), reps, || {
            let _ = std::hint::black_box(
                engine.kkt_sweep(Loss::Gaussian, &reg, &y, &eta, 0.5).unwrap(),
            );
        });
        records.push(Record {
            name: "kkt_sweep",
            n,
            p,
            backend: engine.backend_name(),
            threads: t,
            shards: 1,
            batch: 1,
            design: "resident",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });
        per_thread_mean.push(s.mean);
        let gflops = 2.0 * n as f64 * p as f64 / s.mean / 1e9;
        println!("  -> kkt_sweep throughput: {gflops:.2} GFLOP/s");

        // Batched look-ahead: one sweep + B mask passes vs. B sweeps.
        let lambdas: Vec<f64> = (0..lookahead).map(|i| 0.9 - 0.1 * i as f64).collect();
        let s = bench(
            &format!("kkt_sweep_batch B={lookahead} (threads={t})"),
            reps,
            || {
                let _ = std::hint::black_box(
                    engine
                        .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &lambdas, 0.0)
                        .unwrap(),
                );
            },
        );
        records.push(Record {
            name: "kkt_sweep_batch",
            n,
            p,
            backend: engine.backend_name(),
            threads: t,
            shards: 1,
            batch: lookahead,
            design: "resident",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });
        println!(
            "  -> amortized per-λ: {:.3} µs ({}x over per-λ sweeps)",
            s.mean / lookahead as f64 * 1e6,
            lookahead
        );

        // Algorithm-1 augmentation panel through the backend.
        let e_sz = 90.min(p.saturating_sub(10));
        let base: Vec<usize> = (0..e_sz).collect();
        let next: Vec<usize> = (0..e_sz + 10).collect();
        let s = bench(&format!("Alg-1 panel update (threads={t})"), reps.min(20), || {
            let mut tr = HessianTracker::new(n as f64 * 1e-4).with_engine(&engine);
            tr.rebuild(&dense, &base, None);
            tr.update(&dense, &next, None);
            std::hint::black_box(tr.dim());
        });
        records.push(Record {
            name: "alg1_panel_update",
            n,
            p,
            backend: engine.backend_name(),
            threads: t,
            shards: 1,
            batch: 1,
            design: "resident",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });
    }
    if per_thread_mean.len() == 2 {
        println!(
            "\nkkt_sweep speedup at {} threads: {:.2}x",
            thread_counts[1],
            per_thread_mean[0] / per_thread_mean[1]
        );
    }

    // ------------- sharded suite (--shards S, JSON-recorded) -------------
    // One serial native engine per shard: the per-column kernels are
    // identical to the unsharded backend, so any delta is sharding
    // overhead + pipelined-upload overlap, never numerics.
    if shards >= 1 {
        let engine = RuntimeEngine::native_sharded(shards, 1);
        let t = engine.threads();
        println!("\nsharded suite (n={n}, p={p}, {shards} shard(s), {t} total thread(s))");
        let mut push = |name: &'static str, batch: usize, s: &Summary| {
            records.push(Record {
                name,
                n,
                p,
                backend: "sharded",
                threads: t,
                shards,
                batch,
                design: "resident",
                wall_seconds: s.mean,
                ci_half: s.ci_half,
            });
        };
        // register_design is the pipelined-upload path itself: staging
        // shard k+1 overlaps uploading shard k (UploadStats proves it).
        let s = bench(&format!("register_design ({shards} shards, pipelined)"), reps, || {
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            // Wait for the pipeline so the timing covers the full upload.
            let _ = std::hint::black_box(engine.correlation(&reg, &v).unwrap());
        });
        push("register_design", 1, &s);

        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let s = bench(&format!("correlation X^T r ({shards} shards)"), reps, || {
            let _ = std::hint::black_box(engine.correlation(&reg, &v).unwrap());
        });
        push("correlation", 1, &s);

        let s = bench(&format!("fused kkt_sweep ({shards} shards)"), reps, || {
            let _ = std::hint::black_box(
                engine.kkt_sweep(Loss::Gaussian, &reg, &y, &eta, 0.5).unwrap(),
            );
        });
        push("kkt_sweep", 1, &s);

        let lambdas: Vec<f64> = (0..lookahead).map(|i| 0.9 - 0.1 * i as f64).collect();
        let s = bench(&format!("kkt_sweep_batch B={lookahead} ({shards} shards)"), reps, || {
            let _ = std::hint::black_box(
                engine
                    .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &lambdas, 0.0)
                    .unwrap(),
            );
        });
        push("kkt_sweep_batch", lookahead, &s);

        if let Some(u) = engine.upload_stats() {
            println!(
                "  -> uploads: {} staged, {} uploaded, {} overlapped \
                 (stage {:.1} µs, upload {:.1} µs, stall {:.1} µs)",
                u.staged,
                u.uploaded,
                u.overlapped,
                u.stage_seconds * 1e6,
                u.upload_seconds * 1e6,
                u.stall_seconds * 1e6
            );
        }
    }

    // ------------- out-of-core suite (--design, JSON-recorded) -------------
    // Pack the same design to a temp .hxd, then time the streamed,
    // checksum-verified registration: disk -> shard panels -> engines,
    // with the design never resident in one allocation.
    if args.flag("design") {
        use hessian_screening::storage::{pack_dense, HxdSource, DEFAULT_BLOCK_COLS};
        let k = shards.max(2);
        let path = std::env::temp_dir().join(format!("hxd-bench-{}.hxd", std::process::id()));
        pack_dense(&path, &dense, DEFAULT_BLOCK_COLS, Loss::Gaussian, None)
            .expect("packing the bench design");
        let engine = RuntimeEngine::native_sharded(k, 1);
        println!(
            "\nout-of-core suite (n={n}, p={p}, {k} shard(s), {})",
            path.display()
        );
        let s = bench(&format!("register_hxd ({k} shards, streamed)"), reps, || {
            let src = HxdSource::open(&path).expect("reopening the packed design");
            let reg = engine.register_source(Box::new(src)).unwrap();
            // Wait for the pipeline so the timing covers the full upload.
            let _ = std::hint::black_box(engine.correlation(&reg, &v).unwrap());
        });
        records.push(Record {
            name: "register_hxd",
            n,
            p,
            backend: "sharded",
            threads: engine.threads(),
            shards: k,
            batch: 1,
            design: "hxd",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });
        if let Some(u) = engine.upload_stats() {
            // Cumulative across warmup + reps: the rate is still the
            // honest figure (bytes over seconds spent in read calls).
            let mib = u.bytes_read as f64 / (1024.0 * 1024.0);
            let rate = if u.read_seconds > 0.0 { mib / u.read_seconds } else { 0.0 };
            println!(
                "  -> streamed {mib:.1} MiB total at {rate:.0} MiB/s \
                 (peak in-flight {:.2} MiB, {} staged panels)",
                u.peak_inflight_bytes as f64 / (1024.0 * 1024.0),
                u.staged
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    // ---------------- cv suite (JSON-recorded) ----------------
    // Engine-routed 5-fold CV over zero-copy fold views — the paper's
    // §1 motivating workload end-to-end: one design registration,
    // row-masked fold sweeps, warm per-worker path workspaces. Uses a
    // dedicated smaller shape (CV fits 5 full paths per rep).
    {
        let (cn, cp) = (n.min(200), p.min(500));
        let cdata = SyntheticSpec::new(cn, cp, 5).rho(0.2).snr(4.0).seed(5).generate();
        let cdense = match &cdata.design {
            DesignMatrix::Dense(m) => m.clone(),
            _ => unreachable!(),
        };
        let cv_engine = RuntimeEngine::native_threaded(1);
        let sweep = EngineSweep::new(&cv_engine, &cdense, Loss::Gaussian)
            .unwrap()
            .expect("native backend always binds dense designs");
        let mut cs = CvSettings::default();
        cs.n_folds = 5;
        cs.path.path_length = 20;
        cs.threads = 2;
        cs.engine_threads = 1;
        println!("\ncv suite (n={cn}, p={cp}, 5 folds, 2 fold workers x 1 engine thread)");
        let s = bench("cv 5-fold engine-routed (fold views)", reps.min(10), || {
            let cv = cross_validate_with_engine(
                &cdata.design,
                &cdata.response,
                Loss::Gaussian,
                ScreeningKind::Hessian,
                &cs,
                Some(&sweep),
            );
            std::hint::black_box(cv.idx_min);
        });
        records.push(Record {
            name: "cv_fold_path",
            n: cn,
            p: cp,
            backend: "native",
            threads: 2,
            shards: 1,
            batch: 5,
            design: "resident",
            wall_seconds: s.mean,
            ci_half: s.ci_half,
        });
    }

    // Artifact backend (pjrt feature + `make artifacts`): add a record
    // so the perf trajectory also tracks the artifact-served sweep.
    match RuntimeEngine::load_default() {
        Ok(engine) => {
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            if engine.correlation(&reg, &v).unwrap().is_some() {
                let s = bench(
                    &format!("{} artifact correlation sweep", engine.backend_name()),
                    reps,
                    || {
                        let _ = std::hint::black_box(engine.correlation(&reg, &v).unwrap());
                    },
                );
                records.push(Record {
                    name: "correlation",
                    n,
                    p,
                    backend: engine.backend_name(),
                    threads: engine.threads(),
                    shards: engine.shards(),
                    batch: 1,
                    design: "resident",
                    wall_seconds: s.mean,
                    ci_half: s.ci_half,
                });
            } else {
                println!("(artifact backend has no kernel for {n}x{p}; not benched)");
            }
        }
        Err(_) => println!("(no AOT artifacts / pjrt feature; artifact sweep not benched)"),
    }

    if let Some(path) = args.get("json") {
        write_json(path, &records);
    }
    std::hint::black_box(acc);
}
