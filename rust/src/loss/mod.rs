//! GLM loss functions.
//!
//! The paper's objective is `f(β; X) + λ‖β‖₁` with `f` smooth and convex
//! (§1, eq. 1), instantiated for least-squares (the lasso), logistic
//! regression, and — in Appendix F.9 — Poisson regression. All three are
//! "linear-predictor" losses of the form `f(β) = Σᵢ fᵢ(xᵢᵀβ)` (§3.3.3,
//! eq. 8); this module implements, for each:
//!
//! * the mean function μ(η) and pseudo-residual y − μ(η) (so the
//!   *correlation* c = −∇f = Xᵀ(y − μ));
//! * the Hessian weights wᵢ = fᵢ″(η) used by the GLM Hessian
//!   X_AᵀD(w)X_A, plus the global upper bound on fᵢ″ that §3.3.3 uses
//!   in place of full updates (¼ for logistic, 1 for Gaussian, none for
//!   Poisson);
//! * the primal value, the Fenchel dual value at the scaled dual point
//!   (y − μ)/max(λ, ‖Xᵀ(y − μ)‖∞), and hence the duality gap that the
//!   solver uses as its convergence criterion `G ≤ ε·ζ` (§4);
//! * the paper's normalization constants ζ: ‖y‖² (Gaussian), n·log 2
//!   (logistic), n + Σ log(yᵢ!) (Poisson);
//! * deviance, for the glmnet-style early-stopping rules.
//!
//! Conventions: no intercept (the data layer centers X, and y for the
//! Gaussian case, exactly as in the paper's §4); the "null model" is
//! β = 0.

/// Which GLM loss the problem uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loss {
    /// f(β) = ½‖Xβ − y‖² — the standard lasso.
    Gaussian,
    /// fᵢ(t) = log(1 + eᵗ) − yᵢ t with yᵢ ∈ {0, 1}.
    Logistic,
    /// fᵢ(t) = eᵗ − yᵢ t (+ log yᵢ! constant), yᵢ ∈ {0, 1, 2, …}.
    Poisson,
}

/// Numerically safe x·log(x) with the convention 0·log 0 = 0.
#[inline]
fn xlogx(x: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        x * x.ln()
    }
}

/// log(1 + eᵗ) without overflow.
#[inline]
pub fn log1pexp(t: f64) -> f64 {
    if t > 35.0 {
        t
    } else if t < -35.0 {
        t.exp()
    } else {
        t.exp().ln_1p()
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// ln Γ(x+1) = ln x! via Stirling/Lanczos-free series; exact for the
/// small integer counts synthetic Poisson data produces.
fn ln_factorial(k: f64) -> f64 {
    let k = k.round().max(0.0) as u64;
    if k < 2 {
        return 0.0;
    }
    if k <= 256 {
        let mut s = 0.0;
        for i in 2..=k {
            s += (i as f64).ln();
        }
        s
    } else {
        // Stirling with 1/(12k) correction — plenty for ζ normalization.
        let kf = k as f64;
        kf * kf.ln() - kf + 0.5 * (2.0 * std::f64::consts::PI * kf).ln() + 1.0 / (12.0 * kf)
    }
}

impl Loss {
    /// Mean function μ(η) = fᵢ′(η) + yᵢ ... i.e. E[y | η].
    #[inline]
    pub fn mu(self, eta: f64) -> f64 {
        match self {
            Loss::Gaussian => eta,
            Loss::Logistic => sigmoid(eta),
            Loss::Poisson => eta.min(500.0).exp(),
        }
    }

    /// Hessian weight w(η) = fᵢ″(η).
    #[inline]
    pub fn weight(self, eta: f64) -> f64 {
        match self {
            Loss::Gaussian => 1.0,
            Loss::Logistic => {
                let m = sigmoid(eta);
                m * (1.0 - m)
            }
            Loss::Poisson => eta.min(500.0).exp(),
        }
    }

    /// Global upper bound on fᵢ″, if one exists (§3.3.3): used when the
    /// Hessian is updated with the bound instead of full re-computation.
    #[inline]
    pub fn weight_upper_bound(self) -> Option<f64> {
        match self {
            Loss::Gaussian => Some(1.0),
            Loss::Logistic => Some(0.25),
            Loss::Poisson => None,
        }
    }

    /// Whether Gap-Safe screening is valid for this loss (requires a
    /// Lipschitz gradient; fails for Poisson — paper App. F.9).
    pub fn supports_gap_safe(self) -> bool {
        !matches!(self, Loss::Poisson)
    }

    /// Σᵢ fᵢ(ηᵢ) — the smooth part of the primal. The Poisson constant
    /// Σ log yᵢ! is *included* so that ζ = f(0) exactly as in the paper.
    pub fn value(self, y: &[f64], eta: &[f64]) -> f64 {
        debug_assert_eq!(y.len(), eta.len());
        match self {
            Loss::Gaussian => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    let r = y[i] - eta[i];
                    s += r * r;
                }
                0.5 * s
            }
            Loss::Logistic => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    s += log1pexp(eta[i]) - y[i] * eta[i];
                }
                s
            }
            Loss::Poisson => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    s += eta[i].min(500.0).exp() - y[i] * eta[i] + ln_factorial(y[i]);
                }
                s
            }
        }
    }

    /// out ← y − μ(η): the pseudo-residual whose correlation Xᵀ(y − μ)
    /// is the negative gradient c(λ) of §2.
    pub fn pseudo_residual_into(self, y: &[f64], eta: &[f64], out: &mut [f64]) {
        debug_assert_eq!(y.len(), eta.len());
        debug_assert_eq!(y.len(), out.len());
        match self {
            Loss::Gaussian => {
                for i in 0..y.len() {
                    out[i] = y[i] - eta[i];
                }
            }
            _ => {
                for i in 0..y.len() {
                    out[i] = y[i] - self.mu(eta[i]);
                }
            }
        }
    }

    /// out ← w(η).
    pub fn weights_into(self, eta: &[f64], out: &mut [f64]) {
        debug_assert_eq!(eta.len(), out.len());
        for i in 0..eta.len() {
            out[i] = self.weight(eta[i]);
        }
    }

    /// Convergence normalizer ζ (§4): ‖y‖² (Gaussian), n·log 2
    /// (logistic), n + Σ log yᵢ! (Poisson — App. F.9).
    pub fn zeta(self, y: &[f64]) -> f64 {
        match self {
            Loss::Gaussian => y.iter().map(|v| v * v).sum(),
            Loss::Logistic => y.len() as f64 * std::f64::consts::LN_2,
            Loss::Poisson => {
                y.len() as f64 + y.iter().map(|&v| ln_factorial(v)).sum::<f64>()
            }
        }
    }

    /// Model deviance 2·(f(β) − f_sat): the quantity whose ratio to the
    /// null deviance drives the glmnet-style stopping rules (§4).
    pub fn deviance(self, y: &[f64], eta: &[f64]) -> f64 {
        match self {
            Loss::Gaussian => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    let r = y[i] - eta[i];
                    s += r * r;
                }
                s
            }
            Loss::Logistic => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    s += log1pexp(eta[i]) - y[i] * eta[i];
                }
                2.0 * s
            }
            Loss::Poisson => {
                // f_sat_i = yᵢ − yᵢ log yᵢ (+ log yᵢ!), attained at η = log yᵢ.
                let mut s = 0.0;
                for i in 0..y.len() {
                    s += eta[i].min(500.0).exp() - y[i] * eta[i] - (y[i] - xlogx(y[i]));
                }
                2.0 * s
            }
        }
    }

    /// Null deviance (β = 0 ⇒ η = 0).
    pub fn null_deviance(self, y: &[f64]) -> f64 {
        let eta = vec![0.0; y.len()];
        self.deviance(y, &eta)
    }

    /// Fenchel dual value D(θ) at the *scaled* dual point
    /// θ = resid / s where resid = y − μ(η) and s = max(λ, ‖Xᵀresid‖∞).
    ///
    /// Derivations (fᵢ*(u) the convex conjugate of fᵢ):
    /// * Gaussian: D(θ) = ½‖y‖² − (λ²/2)‖θ − y/λ‖²  (paper eq. 9);
    /// * logistic: D(θ) = −Σ [ xlogx(yᵢ−λθᵢ) + xlogx(1−yᵢ+λθᵢ) ];
    /// * Poisson:  D(θ) = −Σ [ xlogx(yᵢ−λθᵢ) − (yᵢ−λθᵢ) − log yᵢ! ].
    ///
    /// Values are clamped into the dual domain, which can only decrease
    /// D, so the resulting gap stays a valid upper bound on
    /// sub-optimality.
    pub fn dual_value(self, y: &[f64], resid: &[f64], scale: f64, lambda: f64) -> f64 {
        debug_assert!(scale > 0.0);
        let a = lambda / scale; // λθᵢ = a·residᵢ
        match self {
            Loss::Gaussian => {
                // ½‖y‖² − ½‖a·r − y‖²
                let mut s = 0.0;
                for i in 0..y.len() {
                    let d = a * resid[i] - y[i];
                    s += y[i] * y[i] - d * d;
                }
                0.5 * s
            }
            Loss::Logistic => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    let u = (y[i] - a * resid[i]).clamp(0.0, 1.0);
                    s += xlogx(u) + xlogx(1.0 - u);
                }
                -s
            }
            Loss::Poisson => {
                let mut s = 0.0;
                for i in 0..y.len() {
                    let u = (y[i] - a * resid[i]).max(0.0);
                    s += xlogx(u) - u - ln_factorial(y[i]);
                }
                -s
            }
        }
    }

    /// Duality gap G(β, θ) = P(β) − D(θ) for the ℓ₁ problem at `lambda`,
    /// given η = Xβ, the pseudo-residual, ‖Xᵀresid‖∞ and ‖β‖₁.
    /// Guaranteed non-negative up to round-off; clamped at 0.
    pub fn duality_gap(
        self,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        xt_resid_inf: f64,
        lambda: f64,
        l1_norm: f64,
    ) -> f64 {
        let primal = self.value(y, eta) + lambda * l1_norm;
        let scale = lambda.max(xt_resid_inf);
        let dual = self.dual_value(y, resid, scale, lambda);
        (primal - dual).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_and_log1pexp_extremes() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(100.0) > 1.0 - 1e-12);
        assert!(sigmoid(-100.0) < 1e-12);
        assert!((log1pexp(0.0) - std::f64::consts::LN_2).abs() < 1e-15);
        assert!((log1pexp(50.0) - 50.0).abs() < 1e-12);
        assert!(log1pexp(-50.0) < 1e-12);
        assert!(log1pexp(-50.0) > 0.0);
    }

    #[test]
    fn ln_factorial_values() {
        assert_eq!(ln_factorial(0.0), 0.0);
        assert_eq!(ln_factorial(1.0), 0.0);
        assert!((ln_factorial(5.0) - (120.0f64).ln()).abs() < 1e-12);
        // Stirling branch vs. exact sum continuity.
        let exact: f64 = (2..=300u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(300.0) - exact).abs() < 1e-6);
    }

    #[test]
    fn gaussian_value_and_residual() {
        let y = vec![1.0, 2.0, 3.0];
        let eta = vec![0.5, 2.0, 2.0];
        assert!((Loss::Gaussian.value(&y, &eta) - 0.5 * (0.25 + 0.0 + 1.0)).abs() < 1e-14);
        let mut r = vec![0.0; 3];
        Loss::Gaussian.pseudo_residual_into(&y, &eta, &mut r);
        assert_eq!(r, vec![0.5, 0.0, 1.0]);
    }

    #[test]
    fn logistic_gradient_matches_finite_difference() {
        let y = vec![1.0, 0.0, 1.0];
        let eta = vec![0.3, -0.2, 1.5];
        // d/dηᵢ Σ f = μ(ηᵢ) − yᵢ = −residᵢ.
        let mut r = vec![0.0; 3];
        Loss::Logistic.pseudo_residual_into(&y, &eta, &mut r);
        let h = 1e-6;
        for i in 0..3 {
            let mut ep = eta.clone();
            ep[i] += h;
            let mut em = eta.clone();
            em[i] -= h;
            let fd = (Loss::Logistic.value(&y, &ep) - Loss::Logistic.value(&y, &em)) / (2.0 * h);
            assert!((fd + r[i]).abs() < 1e-6, "i={i} fd={fd} r={}", r[i]);
        }
    }

    #[test]
    fn poisson_gradient_and_weight_match_finite_difference() {
        let y = vec![2.0, 0.0, 5.0];
        let eta = vec![0.5, -1.0, 1.2];
        let mut r = vec![0.0; 3];
        Loss::Poisson.pseudo_residual_into(&y, &eta, &mut r);
        let h = 1e-6;
        for i in 0..3 {
            let mut ep = eta.clone();
            ep[i] += h;
            let mut em = eta.clone();
            em[i] -= h;
            let fd = (Loss::Poisson.value(&y, &ep) - Loss::Poisson.value(&y, &em)) / (2.0 * h);
            assert!((fd + r[i]).abs() < 1e-5);
            let fdd = (Loss::Poisson.value(&y, &ep) + Loss::Poisson.value(&y, &em)
                - 2.0 * Loss::Poisson.value(&y, &eta))
                / (h * h);
            assert!((fdd - Loss::Poisson.weight(eta[i])).abs() < 1e-3);
        }
    }

    #[test]
    fn weight_bounds() {
        assert_eq!(Loss::Gaussian.weight_upper_bound(), Some(1.0));
        assert_eq!(Loss::Logistic.weight_upper_bound(), Some(0.25));
        assert_eq!(Loss::Poisson.weight_upper_bound(), None);
        for &eta in &[-3.0, 0.0, 2.5] {
            assert!(Loss::Logistic.weight(eta) <= 0.25 + 1e-15);
        }
        assert!(!Loss::Poisson.supports_gap_safe());
        assert!(Loss::Logistic.supports_gap_safe());
    }

    #[test]
    fn zeta_values() {
        let y = vec![1.0, -2.0, 2.0];
        assert!((Loss::Gaussian.zeta(&y) - 9.0).abs() < 1e-14);
        assert!((Loss::Logistic.zeta(&y) - 3.0 * std::f64::consts::LN_2).abs() < 1e-14);
        let yp = vec![0.0, 1.0, 3.0];
        // n + log 0! + log 1! + log 3! = 3 + 0 + 0 + log 6
        assert!((Loss::Poisson.zeta(&yp) - (3.0 + 6.0f64.ln())).abs() < 1e-12);
        // ζ = f(0) for Poisson, as the paper uses.
        let eta0 = vec![0.0; 3];
        assert!((Loss::Poisson.zeta(&yp) - Loss::Poisson.value(&yp, &eta0)).abs() < 1e-12);
    }

    #[test]
    fn null_deviance_logistic_is_2nlog2_for_balanced() {
        let y = vec![0.0, 1.0, 0.0, 1.0];
        let d = Loss::Logistic.null_deviance(&y);
        assert!((d - 2.0 * 4.0 * std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn poisson_deviance_zero_at_saturation() {
        let y = vec![1.0, 4.0, 2.0];
        let eta: Vec<f64> = y.iter().map(|v: &f64| v.ln()).collect();
        assert!(Loss::Poisson.deviance(&y, &eta).abs() < 1e-12);
    }

    #[test]
    fn gaussian_gap_zero_at_optimum_of_unconstrained() {
        // For λ ≥ ‖Xᵀy‖∞ the solution is β = 0, η = 0 and the gap at the
        // scaled dual point must vanish: P(0) = ½‖y‖², θ = y/s with
        // s = max(λ, ‖Xᵀy‖∞); when s comes from the correlation bound the
        // gap is exactly P − D.
        let y = vec![1.0, -1.0, 0.5];
        let eta = vec![0.0; 3];
        let resid = y.clone();
        // Pretend ‖Xᵀr‖∞ = λ: θ = r/λ, a = 1 ⇒ D = ½‖y‖².
        let g = Loss::Gaussian.duality_gap(&y, &eta, &resid, 1.0, 1.0, 0.0);
        assert!(g.abs() < 1e-14, "gap {g}");
    }

    #[test]
    fn gaps_are_nonnegative_random_points() {
        let y = vec![1.0, 0.0, 1.0, 1.0, 0.0];
        let eta = vec![0.2, -0.4, 0.9, 0.0, 0.3];
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let mut r = vec![0.0; 5];
            loss.pseudo_residual_into(&y, &eta, &mut r);
            let xt = 2.3; // arbitrary claimed correlation bound
            let g = loss.duality_gap(&y, &eta, &r, xt, 0.7, 1.2);
            assert!(g >= 0.0, "{loss:?} gap {g}");
        }
        let yp = vec![1.0, 0.0, 3.0, 2.0, 1.0];
        let mut r = vec![0.0; 5];
        Loss::Poisson.pseudo_residual_into(&yp, &eta, &mut r);
        let g = Loss::Poisson.duality_gap(&yp, &eta, &r, 2.0, 0.7, 1.2);
        assert!(g >= 0.0, "poisson gap {g}");
    }

    #[test]
    fn logistic_gap_shrinks_toward_solution() {
        // 1-predictor problem solved by hand: smaller gap nearer optimum.
        let y = vec![1.0, 0.0];
        let x = [1.0, -1.0];
        let lambda = 0.1;
        let gap_at = |b: f64| {
            let eta = [x[0] * b, x[1] * b];
            let mut r = vec![0.0; 2];
            Loss::Logistic.pseudo_residual_into(&y, &eta, &mut r);
            let xt = (x[0] * r[0] + x[1] * r[1]).abs();
            Loss::Logistic.duality_gap(&y, &eta, &r, xt, lambda, b.abs())
        };
        // KKT: x·(y−μ) = λ·sign(b) ⇒ 2·(1−σ(b))… solve roughly: b* ≈ 2.197−?
        // σ(b)=1−λ/2=0.95 ⇒ b*=ln(0.95/0.05)=2.944.
        let g_far = gap_at(0.0);
        let g_near = gap_at(2.9);
        let g_opt = gap_at((0.95f64 / 0.05).ln());
        assert!(g_near < g_far);
        assert!(g_opt < 1e-6, "gap at optimum {g_opt}");
    }
}
