//! End-to-end driver: the full three-layer stack on one real workload.
//!
//!     make artifacts && cargo run --release --example e2e_path
//!
//! What it proves (recorded in EXPERIMENTS.md §E2E):
//!   1. the AOT pipeline composes — the Pallas/JAX sweep artifact
//!      (L1/L2) is loaded through PJRT and used for the full KKT sweeps
//!      of the rust path driver (L3), with Python nowhere at run time;
//!   2. all four main methods produce the *same* path on the same
//!      workload (cross-method max |Δβ| is printed);
//!   3. the paper's headline metric — relative full-path fit time per
//!      method, plus screened-set sizes — on the n=200, p=20 000
//!      appendix design.

use hessian_screening::data::DesignMatrix;
use hessian_screening::metrics::{fmt_secs, Table};
use hessian_screening::prelude::*;
use hessian_screening::runtime::{EngineSweep, RuntimeEngine};

fn main() {
    // The 200 x 20 000 design matches an AOT artifact shape exactly.
    let (n, p) = (200usize, 20_000usize);
    let data = SyntheticSpec::new(n, p, 20)
        .rho(0.4)
        .snr(2.0)
        .seed(2022)
        .generate();
    println!("workload: n={n} p={p} s=20 rho=0.4 (paper's appendix design)\n");

    // --- Layer composition: a compute backend in the L3 hot path ---
    // PJRT artifacts when available (see `make artifacts` + the `pjrt`
    // feature); the pure-Rust NativeBackend otherwise, so this example
    // exercises the Backend → EngineSweep → driver chain either way.
    let engine = match RuntimeEngine::load_default() {
        Ok(e) => {
            println!(
                "runtime: loaded {} AOT artifacts ({} backend)",
                e.num_ops(),
                e.backend_name()
            );
            e
        }
        Err(e) => {
            println!("runtime: artifacts unavailable ({e}); using the native backend");
            RuntimeEngine::native()
        }
    };

    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };

    let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
    let fit_native = fitter.fit(&data.design, &data.response);
    let fit_engine = EngineSweep::new(&engine, dense, Loss::Gaussian)
        .ok()
        .flatten()
        .map(|sweep| fitter.fit_with_engine(&data.design, &data.response, Some(&sweep)));
    if let Some(fe) = &fit_engine {
        let m = fe.lambdas.len().min(fit_native.lambdas.len());
        let mut max_diff = 0.0f64;
        for k in 0..m {
            let a = fe.beta_dense(k, p);
            let b = fit_native.beta_dense(k, p);
            for j in 0..p {
                max_diff = max_diff.max((a[j] - b[j]).abs());
            }
        }
        println!(
            "{}-swept vs native path: {} steps, max |Δβ| = {max_diff:.2e}  (borderline band rechecked in f64)",
            engine.backend_name(),
            m
        );
        println!(
            "  native {}s vs engine-swept {}s\n",
            fmt_secs(fit_native.total_time),
            fmt_secs(fe.total_time)
        );
    }

    // --- Headline benchmark: all four methods, same workload ---
    let methods = [
        ScreeningKind::Hessian,
        ScreeningKind::Working,
        ScreeningKind::Blitz,
        ScreeningKind::Celer,
    ];
    let mut fits = Vec::new();
    let mut table = Table::new(&[
        "method", "time (s)", "relative", "steps", "passes", "mean screened", "violations",
    ]);
    let mut times = Vec::new();
    for kind in methods {
        let fit = PathFitter::new(Loss::Gaussian, kind).fit(&data.design, &data.response);
        times.push(fit.total_time);
        fits.push((kind, fit));
    }
    let tmin = times.iter().cloned().fold(f64::INFINITY, f64::min);
    for (kind, fit) in &fits {
        table.row(vec![
            kind.name().into(),
            fmt_secs(fit.total_time),
            format!("{:.2}", fit.total_time / tmin),
            format!("{}", fit.lambdas.len()),
            format!("{}", fit.total_passes()),
            format!("{:.0}", fit.mean_screened()),
            format!("{}", fit.total_violations()),
        ]);
    }
    println!("{}", table.render());

    // --- Cross-method agreement (correctness of the whole bench) ---
    // β itself is only determined up to the ε·ζ duality-gap slack (in a
    // ρ=0.4 equicorrelated design, near-degenerate directions make that
    // slack large in coefficient space), so the invariant we check is
    // the *fit*: predictions η = Xβ per step, relative to ‖y‖.
    use hessian_screening::linalg::Design as _;
    let eta_of = |fit: &PathFit, k: usize| -> Vec<f64> {
        let mut eta = vec![0.0; n];
        for &(j, b) in &fit.betas[k] {
            data.design.col_axpy(j, b, &mut eta);
        }
        eta
    };
    let y_norm = data.response.iter().map(|v| v * v).sum::<f64>().sqrt();
    let reference = &fits[0].1;
    let mut worst = 0.0f64;
    for (_, fit) in &fits[1..] {
        let m = fit.lambdas.len().min(reference.lambdas.len());
        for k in 0..m {
            let a = eta_of(reference, k);
            let b = eta_of(fit, k);
            let d: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt();
            worst = worst.max(d / y_norm);
        }
    }
    println!("cross-method max ‖Δη‖/‖y‖ over the path: {worst:.2e}");
    let dev = reference.dev_ratios.last().unwrap();
    println!("final deviance ratio: {dev:.4}");
    assert!(worst < 0.05, "methods disagree: {worst}");
    println!("\ne2e OK: three layers compose; methods agree; Hessian rule fastest or tied.");
}
