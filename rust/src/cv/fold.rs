//! Zero-copy row-masked design views for cross-validation folds.
//!
//! A k-fold CV fit needs k row-subset designs. Materializing them (the
//! old `subset_rows` path) costs k× the design memory and breaks for
//! out-of-core sources, where no resident matrix exists to copy from.
//! [`FoldView`] instead adapts any [`Design`] to a row subset: each
//! column access gathers the kept rows into a compact per-view scratch
//! buffer (via `col_axpy` onto zeros, so the gather is exact for dense
//! and sparse bases alike) and then runs the ordinary [`blas`] kernels
//! over that compact buffer.
//!
//! Bitwise contract: for a dense base, the compact buffer is byte-equal
//! to the corresponding column of a materialized row subset, and every
//! reduction below goes through the same `blas` kernels a materialized
//! design would use — so fold fits through a `FoldView` are bitwise
//! identical to fits on `subset_rows` output (the equivalence suite
//! pins this). The same holds for the engine's masked sweep kernel,
//! which gathers identically before reducing with `blas::dot_panel`.
//!
//! Scratch lives behind a `Mutex` only because `Design: Sync` demands a
//! Sync implementor; in practice each fold worker owns its view, so the
//! lock is uncontended and costs a couple of atomic ops per column
//! gather — noise next to the O(n) gather itself.

use crate::linalg::{blas, Design};
use std::sync::Mutex;

/// A row-masked view over a base design. Implements [`Design`] with
/// `nrows() == rows.len()`; all column reductions see only the kept
/// rows, in their original relative order.
pub struct FoldView<'a, D: Design + ?Sized> {
    base: &'a D,
    rows: Vec<usize>,
    scratch: Mutex<FoldScratch>,
}

/// Reusable gather buffers: one full-length column and two compact
/// columns (two so `gram`/`gram_weighted` can hold both operands under
/// a single lock).
#[derive(Default)]
struct FoldScratch {
    full: Vec<f64>,
    a: Vec<f64>,
    b: Vec<f64>,
}

impl<'a, D: Design + ?Sized> FoldView<'a, D> {
    /// View of the rows where `keep[i]` is true (a CV training fold).
    pub fn new(base: &'a D, keep: &[bool]) -> Self {
        assert_eq!(
            keep.len(),
            base.nrows(),
            "keep mask length must match the base design's row count"
        );
        let rows = keep
            .iter()
            .enumerate()
            .filter_map(|(i, &k)| k.then_some(i))
            .collect();
        Self::from_rows(base, rows)
    }

    /// View of an explicit row-index list (e.g. a holdout set). Indices
    /// must be in-bounds; order is preserved as given.
    pub fn from_rows(base: &'a D, rows: Vec<usize>) -> Self {
        let n = base.nrows();
        assert!(
            rows.iter().all(|&i| i < n),
            "fold row index out of bounds for base design"
        );
        Self {
            base,
            rows,
            scratch: Mutex::new(FoldScratch::default()),
        }
    }

    /// The global (base-design) indices of this view's rows.
    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FoldScratch> {
        // Poison-proof: the scratch holds no invariants across calls
        // (every gather fully overwrites it), so a panic mid-gather on
        // another thread leaves nothing to protect.
        self.scratch.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Gather column `j` of `base` restricted to `rows` into `out`.
/// The full-length staging buffer is zeroed and filled via `col_axpy`
/// with α = 1 (0 + 1·x = x exactly), so the gathered values are the
/// stored column entries bit-for-bit, for dense and sparse bases alike.
fn gather<D: Design + ?Sized>(
    base: &D,
    rows: &[usize],
    j: usize,
    full: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    full.clear();
    full.resize(base.nrows(), 0.0);
    base.col_axpy(j, 1.0, full);
    out.clear();
    out.extend(rows.iter().map(|&i| full[i]));
}

impl<D: Design + ?Sized> Design for FoldView<'_, D> {
    fn nrows(&self) -> usize {
        self.rows.len()
    }

    fn ncols(&self) -> usize {
        self.base.ncols()
    }

    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let mut s = self.lock();
        let FoldScratch { full, a, .. } = &mut *s;
        gather(self.base, &self.rows, j, full, a);
        blas::dot(a, v)
    }

    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]) {
        if alpha == 0.0 {
            return;
        }
        let mut s = self.lock();
        let FoldScratch { full, a, .. } = &mut *s;
        gather(self.base, &self.rows, j, full, a);
        blas::axpy(alpha, a, v);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        let mut s = self.lock();
        let FoldScratch { full, a, .. } = &mut *s;
        gather(self.base, &self.rows, j, full, a);
        blas::sq_norm(a)
    }

    fn gram(&self, i: usize, j: usize) -> f64 {
        let mut s = self.lock();
        let FoldScratch { full, a, b } = &mut *s;
        gather(self.base, &self.rows, i, full, a);
        gather(self.base, &self.rows, j, full, b);
        blas::dot(a, b)
    }

    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64 {
        let mut s = self.lock();
        let FoldScratch { full, a, b } = &mut *s;
        gather(self.base, &self.rows, i, full, a);
        gather(self.base, &self.rows, j, full, b);
        match w {
            None => blas::dot(a, b),
            Some(w) => blas::dot_w(a, b, w),
        }
    }

    fn density(&self) -> f64 {
        self.base.density()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DesignMatrix, SyntheticSpec};
    use crate::linalg::DenseMatrix;

    fn dense_fixture(n: usize, p: usize, seed: u64) -> DenseMatrix {
        let data = SyntheticSpec::new(n, p, 3).rho(0.2).seed(seed).generate();
        match data.design {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!("SyntheticSpec defaults to dense"),
        }
    }

    /// Materialize the kept rows of a dense matrix (local oracle).
    fn dense_subset(m: &DenseMatrix, rows: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(rows.len(), m.ncols());
        for j in 0..m.ncols() {
            let col = m.col(j);
            let ocol = out.col_mut(j);
            for (r, &i) in rows.iter().enumerate() {
                ocol[r] = col[i];
            }
        }
        out
    }

    #[test]
    fn matches_materialized_subset_bitwise() {
        let m = dense_fixture(23, 7, 11);
        let keep: Vec<bool> = (0..23).map(|i| i % 4 != 1).collect();
        let view = FoldView::new(&m, &keep);
        let sub = dense_subset(&m, view.rows());
        assert_eq!(view.nrows(), sub.nrows());
        assert_eq!(view.ncols(), 7);
        let v: Vec<f64> = (0..view.nrows()).map(|i| (i as f64).sin()).collect();
        let w: Vec<f64> = (0..view.nrows()).map(|i| 0.5 + (i % 3) as f64).collect();
        for j in 0..7 {
            // Bitwise: both sides run the same blas kernel over the
            // same compact column bytes.
            assert_eq!(view.col_dot(j, &v).to_bits(), sub.col_dot(j, &v).to_bits());
            assert_eq!(
                view.col_sq_norm(j).to_bits(),
                sub.col_sq_norm(j).to_bits()
            );
            let mut acc_v = v.clone();
            let mut acc_s = v.clone();
            view.col_axpy(j, 0.25, &mut acc_v);
            sub.col_axpy(j, 0.25, &mut acc_s);
            for (a, b) in acc_v.iter().zip(&acc_s) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for i in 0..7 {
                assert_eq!(view.gram(i, j).to_bits(), sub.gram(i, j).to_bits());
                assert_eq!(
                    view.gram_weighted(i, j, Some(&w)).to_bits(),
                    sub.gram_weighted(i, j, Some(&w)).to_bits()
                );
            }
        }
    }

    #[test]
    fn from_rows_preserves_given_order() {
        let m = dense_fixture(10, 3, 2);
        let view = FoldView::from_rows(&m, vec![7, 2, 4]);
        assert_eq!(view.nrows(), 3);
        let col0 = m.col(0);
        let mut eta = vec![0.0; 3];
        view.col_axpy(0, 1.0, &mut eta);
        assert_eq!(eta, vec![col0[7], col0[2], col0[4]]);
    }

    #[test]
    fn sparse_base_gathers_exact_values() {
        let data = SyntheticSpec::new(18, 5, 2).density(0.4).seed(9).generate();
        let (sparse, dense) = match &data.design {
            DesignMatrix::Sparse(m) => (data.design.clone(), DesignMatrix::Dense(m.to_dense())),
            _ => unreachable!(),
        };
        let keep: Vec<bool> = (0..18).map(|i| i % 3 != 0).collect();
        let vs = FoldView::new(&sparse, &keep);
        let vd = FoldView::new(&dense, &keep);
        // The gathered compact columns are identical bytes (axpy onto
        // zeros is exact either way), so all view kernels agree bitwise
        // even though the *bases* reduce in different orders.
        let v: Vec<f64> = (0..vs.nrows()).map(|i| i as f64 - 3.0).collect();
        for j in 0..5 {
            assert_eq!(vs.col_dot(j, &v).to_bits(), vd.col_dot(j, &v).to_bits());
            assert_eq!(vs.col_sq_norm(j).to_bits(), vd.col_sq_norm(j).to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn rejects_out_of_bounds_rows() {
        let m = dense_fixture(6, 2, 1);
        let _ = FoldView::from_rows(&m, vec![0, 6]);
    }
}
