//! K-fold cross-validation for λ selection — an engine workload.
//!
//! The paper's opening motivation (§1): "the optimal λ is typically
//! unknown and must be estimated through model tuning, such as
//! cross-validation. This involves repeated refitting of the model to
//! new batches of data, which is computationally demanding" — which is
//! exactly why path-fitting speed (and hence screening) matters. This
//! module is that workload: k folds, each fitting a full path on a
//! *shared* λ grid (computed from the full data, glmnet-style), scored
//! on the held-out fold, aggregated into a CV curve with the usual
//! minimum-CV and one-standard-error selections.
//!
//! Execution model (the fast path, [`cross_validate_with_engine`]):
//!
//! * **Zero-copy folds.** Each training fold is a [`FoldView`] — a
//!   row-masked adapter over the *one* full design, so a 10-fold CV
//!   holds one design in memory, not eleven. The same view works over
//!   resident matrices and over [`crate::runtime::ShardedDesignView`]s
//!   backed by out-of-core `.hxd` sources (the design registers once;
//!   folds never re-register).
//! * **Engine-routed sweeps.** With an [`EngineSweep`] binding, each
//!   fold clones it via [`EngineSweep::fold`] (an `Arc` share of the
//!   registered design) and the path driver's full KKT sweeps run
//!   through the backend's row-masked kernel on the engine's threads.
//! * **Warm fold paths.** Folds dispatch on the
//!   [`crate::coordinator::Coordinator`]; each fold worker owns one
//!   reusable [`Workspace`] (via `Coordinator::run_with`), so
//!   consecutive folds on a worker reuse the grown solver/sweep arenas.
//!   The oversubscription policy `cv_threads × engine_threads ≤ T` is
//!   [`thread_plan`]'s contract.
//!
//! Determinism contract: the CV curve, selections, and full-refit
//! coefficients are bit-identical across `threads ∈ {1, 4}`,
//! engine-routed vs. host-path folds, fold views vs. materialized
//! subsets, and `.hxd`-sourced vs. resident designs
//! (`rust/tests/cv_equivalence.rs`). To keep the engine path inside
//! the contract, fold bindings and the full refit disable look-ahead
//! batching — its Gap-Safe masks change screened sets and hence
//! coordinate-descent visit order (see [`EngineSweep::fold`]).

use crate::coordinator::Coordinator;
use crate::data::DesignMatrix;
use crate::linalg::{CscMatrix, DenseMatrix, Design};
use crate::loss::Loss;
use crate::metrics::Summary;
use crate::path::{lambda_grid, PathFit, PathFitter, PathSettings, Workspace};
use crate::rng::Xoshiro256pp;
use crate::runtime::EngineSweep;
use crate::screening::ScreeningKind;
use std::time::Instant;

mod fold;
pub use fold::FoldView;

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvSettings {
    pub n_folds: usize,
    /// Fold-assignment shuffle seed (`hx cv --folds-seed`).
    pub seed: u64,
    pub path: PathSettings,
    /// Fold-level workers (the coordinator's thread count).
    pub threads: usize,
    /// Engine threads per fold worker; 0 derives the budget split via
    /// [`thread_plan`] (callers building their own engine pass the
    /// resolved value through so `CvStats` reports it).
    pub engine_threads: usize,
}

impl Default for CvSettings {
    fn default() -> Self {
        Self {
            n_folds: 10,
            seed: 0,
            path: PathSettings::default(),
            threads: Coordinator::auto().threads,
            engine_threads: 1,
        }
    }
}

/// Split a total thread budget between fold workers and per-fold
/// engine threads: the oversubscription policy is
/// `cv_threads × engine_threads ≤ total`. Fold workers are capped by
/// the fold count (idle workers are pure overhead) and leftover budget
/// goes to the engines; an explicit `engine_threads` request (> 0) is
/// clamped so the product still respects the budget.
pub fn thread_plan(total: usize, n_folds: usize, engine_threads: usize) -> (usize, usize) {
    let total = total.max(1);
    let cv = total.min(n_folds.max(1));
    let cap = (total / cv).max(1);
    let eng = if engine_threads == 0 {
        cap
    } else {
        engine_threads.min(cap)
    };
    (cv, eng)
}

/// Per-fold observability record, summed from the fold fit's
/// [`crate::path::StepStats`] plus the fold's own wall clock.
#[derive(Clone, Debug, Default)]
pub struct FoldStats {
    pub fold: usize,
    /// Fold wall time: fit + holdout scoring.
    pub wall_seconds: f64,
    pub t_cd: f64,
    pub t_kkt: f64,
    pub t_sweep: f64,
    pub t_hessian: f64,
    pub t_screen: f64,
    /// Workspace arena growth over the fold's path (0 in steady state
    /// once a worker's arenas have grown — the warm-fold signal).
    pub alloc_bytes: usize,
    pub mean_screened: f64,
    pub steps: usize,
    pub passes: usize,
    pub full_sweeps: usize,
}

/// Observability for one CV run: per-fold records plus the thread /
/// routing configuration that produced them. Printed by
/// `hx cv --profile` and emitted in the bench JSON.
#[derive(Clone, Debug, Default)]
pub struct CvStats {
    pub folds: Vec<FoldStats>,
    /// Fold-level workers used.
    pub cv_threads: usize,
    /// Engine threads per fold worker (1 when host-path).
    pub engine_threads: usize,
    /// Engine shard count (1 when unsharded or host-path).
    pub engine_shards: usize,
    /// Whether fold sweeps were engine-routed (an [`EngineSweep`]
    /// binding was supplied).
    pub routed: bool,
}

impl CvStats {
    /// Aggregate a per-fold field into a [`Summary`] (mean/sd/CI over
    /// folds).
    pub fn summarize(&self, f: impl Fn(&FoldStats) -> f64) -> Summary {
        Summary::over(&self.folds, f)
    }
}

/// Result of a cross-validated path.
#[derive(Clone, Debug)]
pub struct CvFit {
    pub lambdas: Vec<f64>,
    /// Mean held-out deviance per λ (the CV curve).
    pub cv_mean: Vec<f64>,
    /// Standard error of the fold deviances per λ.
    pub cv_se: Vec<f64>,
    /// Index of the CV-minimizing λ.
    pub idx_min: usize,
    /// Largest λ within one SE of the minimum (the "1-SE rule").
    pub idx_1se: usize,
    /// Final path refit on the full data.
    pub full_fit: PathFit,
    /// Per-fold profile of the run.
    pub stats: CvStats,
}

impl CvFit {
    pub fn lambda_min(&self) -> f64 {
        self.lambdas[self.idx_min]
    }

    pub fn lambda_1se(&self) -> f64 {
        self.lambdas[self.idx_1se]
    }

    /// Coefficients at the CV-selected λ (sparse pairs). Falls back to
    /// the last fitted step when the refit's path ended early, and to
    /// the empty (null-model) vector when it has no steps at all.
    pub fn selected_coefs(&self, one_se: bool) -> &[(usize, f64)] {
        let idx = if one_se { self.idx_1se } else { self.idx_min };
        self.full_fit
            .betas
            .get(idx)
            .or_else(|| self.full_fit.betas.last())
            .map_or(&[], |b| b.as_slice())
    }
}

/// Assign each observation to a fold (balanced, shuffled).
pub fn fold_assignments(n: usize, k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2, "need at least 2 folds");
    assert!(n >= k, "more folds than observations");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let mut fold = vec![0usize; n];
    for (pos, &i) in idx.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Materialize the rows of a design (dense or sparse) where `keep[i]`.
///
/// **Test oracle only.** The CV fold loop never materializes designs —
/// it fits through [`FoldView`]s — but the equivalence suite keeps this
/// copy path alive to prove the views bit-identical to real subsets.
pub fn subset_rows(design: &DesignMatrix, keep: &[bool]) -> DesignMatrix {
    let n_new = keep.iter().filter(|&&k| k).count();
    let mut row_map = vec![usize::MAX; design.nrows()];
    let mut r = 0;
    for i in 0..design.nrows() {
        if keep[i] {
            row_map[i] = r;
            r += 1;
        }
    }
    match design {
        DesignMatrix::Dense(m) => {
            let mut out = DenseMatrix::zeros(n_new, m.ncols());
            for j in 0..m.ncols() {
                let col = m.col(j);
                let ocol = out.col_mut(j);
                for i in 0..col.len() {
                    if keep[i] {
                        ocol[row_map[i]] = col[i];
                    }
                }
            }
            DesignMatrix::Dense(out)
        }
        DesignMatrix::Sparse(m) => {
            let mut triplets = Vec::new();
            for j in 0..m.ncols() {
                let (ri, vals) = m.col(j);
                for (&i, &v) in ri.iter().zip(vals) {
                    if keep[i as usize] {
                        triplets.push((row_map[i as usize], j, v));
                    }
                }
            }
            DesignMatrix::Sparse(CscMatrix::from_triplets(n_new, m.ncols(), &triplets))
        }
    }
}

/// Per-λ held-out deviances for one fold. The compact response and η
/// buffers are hoisted out of the per-λ loop (the old implementation
/// allocated three n-length vectors for every λ × fold), and η is
/// accumulated over holdout rows only — O(|holdout|) per nonzero
/// coefficient instead of O(n). The holdout gather goes through a
/// [`FoldView`], so values are bitwise what the full-η path computed.
fn holdout_deviances<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    holdout: &[usize],
    fit: &PathFit,
    grid_len: usize,
    loss: Loss,
) -> Vec<f64> {
    let hold = FoldView::from_rows(design, holdout.to_vec());
    let yh: Vec<f64> = holdout.iter().map(|&i| y[i]).collect();
    let mut eta_h = vec![0.0; holdout.len()];
    (0..grid_len)
        .map(|k| {
            // Fall back to the last fitted step when the fold's path
            // ended early; an empty path means the null model.
            let beta: &[(usize, f64)] = fit
                .betas
                .get(k)
                .or_else(|| fit.betas.last())
                .map_or(&[], |b| b.as_slice());
            for v in eta_h.iter_mut() {
                *v = 0.0;
            }
            for &(j, b) in beta {
                hold.col_axpy(j, b, &mut eta_h);
            }
            loss.deviance(&yh, &eta_h) / holdout.len().max(1) as f64
        })
        .collect()
}

fn fold_stats(fold: usize, fit: &PathFit, wall_seconds: f64) -> FoldStats {
    let mut fs = FoldStats {
        fold,
        wall_seconds,
        mean_screened: fit.mean_screened(),
        steps: fit.steps.len(),
        passes: fit.total_passes(),
        ..FoldStats::default()
    };
    for s in &fit.steps {
        fs.t_cd += s.t_cd;
        fs.t_kkt += s.t_kkt;
        fs.t_sweep += s.t_sweep;
        fs.t_hessian += s.t_hessian;
        fs.t_screen += s.t_screen;
        fs.alloc_bytes += s.alloc_bytes;
        fs.full_sweeps += s.full_sweeps;
    }
    fs
}

/// Run k-fold cross-validation on the host path (no engine). The λ
/// grid is fixed from the *full* data so fold curves are comparable
/// (glmnet's convention). Folds fit through zero-copy [`FoldView`]s.
pub fn cross_validate<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    kind: ScreeningKind,
    settings: &CvSettings,
) -> CvFit {
    cross_validate_with_engine(design, y, loss, kind, settings, None)
}

/// Run k-fold cross-validation, optionally routing fold sweeps through
/// an [`EngineSweep`] binding (see the module docs for the execution
/// model and determinism contract). `engine`, when given, must be
/// bound to the same design/loss; each fold derives a masked binding
/// from it via [`EngineSweep::fold`] and the full refit runs through
/// [`EngineSweep::without_lookahead`].
pub fn cross_validate_with_engine<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    kind: ScreeningKind,
    settings: &CvSettings,
    engine: Option<&EngineSweep>,
) -> CvFit {
    let n = design.nrows();
    let p = design.ncols();

    // Shared λ grid from the full data.
    let mut resid = vec![0.0; n];
    let eta0 = vec![0.0; n];
    loss.pseudo_residual_into(y, &eta0, &mut resid);
    let lambda_max = (0..p)
        .map(|j| design.col_dot(j, &resid).abs())
        .fold(0.0f64, f64::max);
    let ratio = settings
        .path
        .lambda_min_ratio
        .unwrap_or_else(|| crate::path::default_lambda_min_ratio(n, p));
    let lambdas = lambda_grid(lambda_max, ratio, settings.path.path_length);

    let folds = fold_assignments(n, settings.n_folds, settings.seed);
    let jobs: Vec<usize> = (0..settings.n_folds).collect();
    let cv_threads = settings.threads.max(1).min(settings.n_folds);
    let coord = Coordinator::new(cv_threads);
    // One reusable path workspace per fold worker: consecutive folds
    // on a worker reuse the grown arenas (`run_with`'s per-worker
    // state), so steady-state folds report `alloc_bytes ≈ 0`.
    let outcomes: Vec<(Vec<f64>, FoldStats)> = coord.run_with(jobs, Workspace::default, |ws, _, &f| {
        let t_fold = Instant::now();
        let keep: Vec<bool> = folds.iter().map(|&g| g != f).collect();
        let view = FoldView::new(design, &keep);
        let train_y: Vec<f64> = view.rows().iter().map(|&i| y[i]).collect();
        let holdout: Vec<usize> = (0..n).filter(|&i| !keep[i]).collect();
        // Fold binding: Arc-shared registered design, masked sweeps,
        // look-ahead off (determinism contract).
        let es_fold = engine.map(|es| es.fold(view.rows().to_vec()));
        let mut ps = settings.path.clone();
        ps.lambda_path = Some(lambdas.clone());
        // no early stopping inside folds: curves must align on the grid
        ps.dev_ratio_max = 1.0;
        ps.dev_change_min = 0.0;
        let fit = PathFitter::new(loss, kind)
            .with_settings(ps)
            .fit_with_workspace(&view, &train_y, es_fold.as_ref(), ws);
        let devs = holdout_deviances(design, y, &holdout, &fit, lambdas.len(), loss);
        let stats = fold_stats(f, &fit, t_fold.elapsed().as_secs_f64());
        (devs, stats)
    });

    let m = lambdas.len();
    let mut cv_mean = Vec::with_capacity(m);
    let mut cv_se = Vec::with_capacity(m);
    for k in 0..m {
        let vals: Vec<f64> = outcomes.iter().map(|(devs, _)| devs[k]).collect();
        let s = Summary::of(&vals);
        cv_mean.push(s.mean);
        cv_se.push(s.sd / (vals.len() as f64).sqrt());
    }
    let idx_min = (0..m)
        .min_by(|&a, &b| cv_mean[a].total_cmp(&cv_mean[b]))
        .unwrap_or(0);
    // 1-SE rule: the largest λ (smallest index) whose CV mean is within
    // one SE of the minimum.
    let threshold = cv_mean.get(idx_min).copied().unwrap_or(f64::NAN)
        + cv_se.get(idx_min).copied().unwrap_or(0.0);
    let idx_1se = (0..=idx_min)
        .find(|&k| cv_mean[k] <= threshold)
        .unwrap_or(idx_min);

    let mut ps = settings.path.clone();
    ps.lambda_path = Some(lambdas.clone());
    ps.dev_ratio_max = 1.0;
    ps.dev_change_min = 0.0;
    // Full refit with look-ahead off so the engine-routed and host-path
    // refits agree bitwise (same reason as the fold bindings).
    let es_full = engine.map(|es| es.without_lookahead());
    let full_fit = PathFitter::new(loss, kind)
        .with_settings(ps)
        .fit_with_engine(design, y, es_full.as_ref());

    let stats = CvStats {
        folds: outcomes.into_iter().map(|(_, fs)| fs).collect(),
        cv_threads,
        engine_threads: engine.map_or(1, |es| es.engine.threads()),
        engine_shards: engine.map_or(1, |es| es.engine.shards()),
        routed: engine.is_some(),
    };

    CvFit {
        lambdas,
        cv_mean,
        cv_se,
        idx_min,
        idx_1se,
        full_fit,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::runtime::RuntimeEngine;

    #[test]
    fn fold_assignments_balanced_and_deterministic() {
        let f = fold_assignments(103, 5, 7);
        assert_eq!(f.len(), 103);
        let mut counts = [0usize; 5];
        for &g in &f {
            counts[g] += 1;
        }
        for &c in &counts {
            assert!((20..=21).contains(&c), "unbalanced: {counts:?}");
        }
        assert_eq!(f, fold_assignments(103, 5, 7));
        assert_ne!(f, fold_assignments(103, 5, 8));
    }

    #[test]
    #[should_panic(expected = "at least 2 folds")]
    fn rejects_single_fold() {
        let _ = fold_assignments(10, 1, 0);
    }

    #[test]
    fn thread_plan_respects_the_budget() {
        // cv × engine ≤ total, always.
        for total in 1..=9 {
            for folds in 2..=12 {
                for eng in 0..=4 {
                    let (cv, et) = thread_plan(total, folds, eng);
                    assert!(cv * et <= total.max(1), "({total},{folds},{eng})");
                    assert!(cv >= 1 && et >= 1);
                    assert!(cv <= folds);
                }
            }
        }
        // Budget split: folds first, leftover into the engines.
        assert_eq!(thread_plan(8, 10, 0), (8, 1));
        assert_eq!(thread_plan(8, 4, 0), (4, 2));
        assert_eq!(thread_plan(8, 4, 8), (4, 2)); // request clamped
        assert_eq!(thread_plan(1, 10, 0), (1, 1));
        assert_eq!(thread_plan(6, 2, 1), (2, 1)); // explicit request kept
        assert_eq!(thread_plan(0, 5, 0), (1, 1)); // degenerate budget
    }

    #[test]
    fn subset_rows_dense_and_sparse_agree() {
        let data = SyntheticSpec::new(20, 6, 2).density(0.4).seed(1).generate();
        let sparse = data.design.clone();
        let dense = match &sparse {
            DesignMatrix::Sparse(m) => DesignMatrix::Dense(m.to_dense()),
            _ => unreachable!(),
        };
        let keep: Vec<bool> = (0..20).map(|i| i % 3 != 0).collect();
        let sd = subset_rows(&dense, &keep);
        let ss = subset_rows(&sparse, &keep);
        assert_eq!(sd.nrows(), ss.nrows());
        let v: Vec<f64> = (0..sd.nrows()).map(|i| i as f64).collect();
        for j in 0..6 {
            assert!((sd.col_dot(j, &v) - ss.col_dot(j, &v)).abs() < 1e-12);
        }
    }

    #[test]
    fn selected_coefs_empty_path_returns_empty() {
        // Regression: an empty full-fit path used to underflow
        // `betas.len() - 1` and panic.
        let cv = CvFit {
            lambdas: vec![1.0],
            cv_mean: vec![0.5],
            cv_se: vec![0.1],
            idx_min: 0,
            idx_1se: 0,
            full_fit: PathFit {
                lambdas: Vec::new(),
                betas: Vec::new(),
                dev_ratios: Vec::new(),
                steps: Vec::new(),
                total_time: 0.0,
                loss: Loss::Gaussian,
                kind: ScreeningKind::Hessian,
                converged: true,
            },
            stats: CvStats::default(),
        };
        assert!(cv.selected_coefs(false).is_empty());
        assert!(cv.selected_coefs(true).is_empty());
    }

    #[test]
    fn cv_selects_reasonable_lambda_gaussian() {
        let data = SyntheticSpec::new(150, 40, 4).rho(0.2).snr(5.0).seed(3).generate();
        let mut settings = CvSettings::default();
        settings.n_folds = 5;
        settings.path.path_length = 40;
        settings.threads = 2;
        let cv = cross_validate(
            &data.design,
            &data.response,
            Loss::Gaussian,
            ScreeningKind::Hessian,
            &settings,
        );
        assert_eq!(cv.cv_mean.len(), cv.lambdas.len());
        // The CV minimum is in the interior (not the null model, not the
        // end of the path) for a well-posed high-SNR problem.
        assert!(cv.idx_min > 0, "CV chose the null model");
        // 1-SE λ is at least as large as the min-CV λ.
        assert!(cv.lambda_1se() >= cv.lambda_min());
        // Selected model contains true signals.
        let coefs = cv.selected_coefs(false);
        assert!(!coefs.is_empty());
        let truth = data.beta_true.as_ref().unwrap();
        let hits = coefs
            .iter()
            .filter(|&&(j, _)| truth[j] != 0.0)
            .count();
        assert!(hits >= 3, "only {hits}/4 signals recovered");
        // Profile record: one entry per fold, host-path routing.
        assert_eq!(cv.stats.folds.len(), 5);
        assert!(!cv.stats.routed);
        assert!(cv.stats.folds.iter().all(|f| f.steps > 0 && f.passes > 0));
        assert!(cv.stats.summarize(|f| f.wall_seconds).mean > 0.0);
    }

    #[test]
    fn cv_logistic_runs() {
        let data = SyntheticSpec::new(120, 20, 3)
            .loss(Loss::Logistic)
            .snr(3.0)
            .signal_scale(1.5)
            .seed(4)
            .generate();
        let mut settings = CvSettings::default();
        settings.n_folds = 4;
        settings.path.path_length = 25;
        settings.threads = 2;
        let cv = cross_validate(
            &data.design,
            &data.response,
            Loss::Logistic,
            ScreeningKind::Working,
            &settings,
        );
        // CV curve finite and the minimum beats the null model's score.
        assert!(cv.cv_mean.iter().all(|v| v.is_finite()));
        assert!(cv.cv_mean[cv.idx_min] < cv.cv_mean[0]);
    }

    #[test]
    fn engine_routed_cv_matches_host_path_bitwise() {
        // The unit-scale version of the equivalence suite's contract:
        // same data, same settings, engine-routed vs. host-path — the
        // curve, the selections, and the refit must agree bit-for-bit.
        let data = SyntheticSpec::new(80, 24, 3).rho(0.2).snr(4.0).seed(6).generate();
        let dense = match &data.design {
            DesignMatrix::Dense(m) => m.clone(),
            _ => unreachable!(),
        };
        let mut settings = CvSettings::default();
        settings.n_folds = 4;
        settings.path.path_length = 15;
        settings.threads = 2;
        let host = cross_validate(
            &data.design,
            &data.response,
            Loss::Gaussian,
            ScreeningKind::Hessian,
            &settings,
        );
        let engine = RuntimeEngine::native_threaded(2);
        let sweep = EngineSweep::new(&engine, &dense, Loss::Gaussian)
            .unwrap()
            .expect("native always binds");
        let routed = cross_validate_with_engine(
            &data.design,
            &data.response,
            Loss::Gaussian,
            ScreeningKind::Hessian,
            &settings,
            Some(&sweep),
        );
        assert_eq!(host.lambdas, routed.lambdas);
        for k in 0..host.cv_mean.len() {
            assert_eq!(
                host.cv_mean[k].to_bits(),
                routed.cv_mean[k].to_bits(),
                "cv curve differs at λ index {k}"
            );
        }
        assert_eq!(host.idx_min, routed.idx_min);
        assert_eq!(host.idx_1se, routed.idx_1se);
        assert_eq!(host.full_fit.betas, routed.full_fit.betas);
        assert!(routed.stats.routed);
        assert_eq!(routed.stats.engine_threads, 2);
    }
}
