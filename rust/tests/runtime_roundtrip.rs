//! Integration: the compute-backend bridge, end to end.
//!
//! The native-backend roundtrips run unconditionally — they need no
//! artifacts and no feature flags, so `cargo test` exercises the whole
//! Backend → EngineSweep → path-driver chain on a fresh checkout.
//!
//! The PJRT artifact tests are compiled only with `--features pjrt`
//! and still skip politely when `make artifacts` has not been run, so
//! `cargo test --features pjrt` stays green without a Python toolchain
//! (`make test` always builds artifacts first).

use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::Design;
use hessian_screening::loss::Loss;
use hessian_screening::path::PathFitter;
use hessian_screening::runtime::{EngineSweep, RuntimeEngine};
use hessian_screening::screening::ScreeningKind;

fn dense_of(data: &hessian_screening::data::Dataset) -> &hessian_screening::linalg::DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

// ---------------------------------------------------------------------
// Native backend: unconditional roundtrips.
// ---------------------------------------------------------------------

#[test]
fn native_xt_r_matches_direct_sweep() {
    let engine = RuntimeEngine::native();
    let (n, p) = (120, 800);
    let data = SyntheticSpec::new(n, p, 8).rho(0.3).seed(3).generate();
    let dense = dense_of(&data);
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    let r = &data.response;
    let c = engine.correlation(&reg, r).unwrap().expect("native kernel");
    assert_eq!(c.len(), p);
    for j in 0..p {
        let native = dense.col_dot(j, r);
        assert!(
            (c[j] - native).abs() < 1e-10 * (1.0 + native.abs()),
            "col {j}: {} vs {}",
            c[j],
            native
        );
    }
}

#[test]
fn native_kkt_sweep_gaussian_and_logistic() {
    let engine = RuntimeEngine::native();
    let (n, p) = (100, 400);
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 8)
            .rho(0.2)
            .loss(loss)
            .seed(4)
            .generate();
        let dense = dense_of(&data);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let eta = vec![0.1; n];
        let (c, resid) = engine
            .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
            .unwrap()
            .expect("native kernel");
        let mut resid_native = vec![0.0; n];
        loss.pseudo_residual_into(&data.response, &eta, &mut resid_native);
        for i in 0..n {
            assert!(
                (resid[i] - resid_native[i]).abs() < 1e-12,
                "{loss:?} resid {i}"
            );
        }
        for j in 0..p {
            let native = dense.col_dot(j, &resid_native);
            assert!(
                (c[j] - native).abs() < 1e-10 * (1.0 + native.abs()),
                "{loss:?} col {j}: {} vs {native}",
                c[j]
            );
        }
    }
}

#[test]
fn native_gram_block_matches_weighted_gram() {
    let engine = RuntimeEngine::native();
    let (e, d, n) = (32, 8, 100);
    let data = SyntheticSpec::new(n, e + d, 5).seed(5).generate();
    let dense = dense_of(&data);
    // Row-major (e, n) panels == concatenated column-major columns.
    let mut xe_t = Vec::with_capacity(e * n);
    for j in 0..e {
        xe_t.extend_from_slice(dense.col(j));
    }
    let mut xd_t = Vec::with_capacity(d * n);
    for j in e..e + d {
        xd_t.extend_from_slice(dense.col(j));
    }
    let w = vec![0.25; n];
    let g = engine
        .gram_block(&xe_t, &w, &xd_t, e, d, n)
        .unwrap()
        .expect("native kernel");
    assert_eq!(g.len(), e * d);
    for a in 0..e {
        for b in 0..d {
            let native = 0.25 * dense.gram(a, e + b);
            let got = g[a * d + b]; // row-major (e, d)
            assert!(
                (got - native).abs() < 1e-10 * (1.0 + native.abs()),
                "panel ({a},{b}): {got} vs {native}"
            );
        }
    }
}

#[test]
fn native_engine_swept_path_equals_plain_path() {
    let engine = RuntimeEngine::native();
    let (n, p) = (150, 600);
    let data = SyntheticSpec::new(n, p, 10).rho(0.4).seed(6).generate();
    let dense = dense_of(&data);
    let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
        .unwrap()
        .expect("native backend always binds");
    let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
    let native = fitter.fit(&data.design, &data.response);
    let swept = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
    assert_eq!(native.lambdas.len(), swept.lambdas.len());
    let m = native.lambdas.len();
    for k in 0..m {
        let a = native.beta_dense(k, p);
        let b = swept.beta_dense(k, p);
        for j in 0..p {
            assert!(
                (a[j] - b[j]).abs() < 1e-6,
                "step {k} coef {j}: {} vs {}",
                a[j],
                b[j]
            );
        }
    }
}

#[test]
fn native_poisson_has_no_fused_sweep() {
    // Poisson has no fused sweep by design (no Lipschitz gradient), so
    // EngineSweep::new must return None and the driver stays native.
    let engine = RuntimeEngine::native();
    assert!(!engine.supports_sweep(Loss::Poisson, 200, 2_000));
    let data = SyntheticSpec::new(40, 30, 3).seed(7).generate();
    let dense = dense_of(&data);
    assert!(EngineSweep::new(&engine, dense, Loss::Poisson)
        .unwrap()
        .is_none());
}

#[test]
fn load_dir_without_artifacts_errors_cleanly() {
    // Default builds: feature-gate error. `pjrt` builds: missing
    // manifest. Either way an Err the CLI can print — never a panic.
    let err = RuntimeEngine::load_dir(std::path::Path::new("/nonexistent-dir-xyz"));
    assert!(err.is_err());
}

// ---------------------------------------------------------------------
// PJRT artifact tests: compiled only with `--features pjrt`, and they
// skip politely when `make artifacts` has not produced the artifacts.
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;

    fn engine() -> Option<RuntimeEngine> {
        // tests run from the package root
        match RuntimeEngine::load_default() {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("skipping PJRT integration test: {err}");
                None
            }
        }
    }

    #[test]
    fn xt_r_artifact_matches_native_within_f32() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        let data = SyntheticSpec::new(n, p, 10).rho(0.3).seed(3).generate();
        let dense = dense_of(&data);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let r = &data.response;
        let c = engine.correlation(&reg, r).unwrap().expect("artifact");
        assert_eq!(c.len(), p);
        let scale: f64 = r.iter().map(|v| v * v).sum::<f64>().sqrt() * (n as f64).sqrt();
        for j in 0..p {
            let native = dense.col_dot(j, r);
            assert!(
                (c[j] - native).abs() < 1e-4 * scale.max(1.0),
                "col {j}: {} vs {}",
                c[j],
                native
            );
        }
    }

    #[test]
    fn kkt_sweep_artifact_gaussian_and_logistic() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let data = SyntheticSpec::new(n, p, 10)
                .rho(0.2)
                .loss(loss)
                .seed(4)
                .generate();
            let dense = dense_of(&data);
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            let eta = vec![0.1; n];
            let (c, resid) = engine
                .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
                .unwrap()
                .expect("artifact");
            let mut resid_native = vec![0.0; n];
            loss.pseudo_residual_into(&data.response, &eta, &mut resid_native);
            for i in 0..n {
                assert!(
                    (resid[i] - resid_native[i]).abs() < 1e-5,
                    "{loss:?} resid {i}"
                );
            }
            for j in (0..p).step_by(97) {
                let native = dense.col_dot(j, &resid_native);
                assert!(
                    (c[j] - native).abs() < 1e-3 * (1.0 + native.abs()),
                    "{loss:?} col {j}: {} vs {native}",
                    c[j]
                );
            }
        }
    }

    #[test]
    fn engine_swept_path_equals_native_path() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        let data = SyntheticSpec::new(n, p, 10).rho(0.4).seed(6).generate();
        let dense = dense_of(&data);
        let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
            .unwrap()
            .expect("sweep artifact for 200x2000");
        let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
        let native = fitter.fit(&data.design, &data.response);
        let swept = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
        assert_eq!(native.lambdas.len(), swept.lambdas.len());
        let m = native.lambdas.len();
        for k in 0..m {
            let a = native.beta_dense(k, p);
            let b = swept.beta_dense(k, p);
            for j in 0..p {
                assert!(
                    (a[j] - b[j]).abs() < 1e-3,
                    "step {k} coef {j}: {} vs {}",
                    a[j],
                    b[j]
                );
            }
        }
    }

    #[test]
    fn unsupported_shapes_fall_back_to_native() {
        let Some(engine) = engine() else { return };
        // 123 x 456 has no artifact: supports_sweep must say no, and
        // EngineSweep::new must return None so the driver stays native.
        assert!(!engine.supports_sweep(Loss::Gaussian, 123, 456));
        let data = SyntheticSpec::new(123, 456, 5).seed(7).generate();
        let dense = dense_of(&data);
        assert!(EngineSweep::new(&engine, dense, Loss::Gaussian)
            .unwrap()
            .is_none());
    }
}
