//! Data substrate: design-matrix container, standardization, synthetic
//! generators (paper §4.1) and simulated analogues of the paper's real
//! data sets (paper §4.2 / Appendix E; see DESIGN.md §3 for the
//! substitution rationale).

mod datasets;
mod standardize;
mod synthetic;

pub use datasets::{dataset_by_name, dataset_catalog, DatasetSpec};
pub use standardize::{standardize, Standardization};
pub use synthetic::{CorrelationStructure, SyntheticSpec};

use crate::linalg::{CscMatrix, DenseMatrix, Design};

/// A design matrix that is either dense or sparse CSC. Implements
/// [`Design`] by enum dispatch so the solver code is storage-agnostic
/// without virtual calls in the inner loops.
#[derive(Clone, Debug)]
pub enum DesignMatrix {
    Dense(DenseMatrix),
    Sparse(CscMatrix),
}

impl DesignMatrix {
    pub fn is_sparse(&self) -> bool {
        matches!(self, DesignMatrix::Sparse(_))
    }
}

impl Design for DesignMatrix {
    #[inline]
    fn nrows(&self) -> usize {
        match self {
            DesignMatrix::Dense(m) => m.nrows(),
            DesignMatrix::Sparse(m) => m.nrows(),
        }
    }

    #[inline]
    fn ncols(&self) -> usize {
        match self {
            DesignMatrix::Dense(m) => m.ncols(),
            DesignMatrix::Sparse(m) => m.ncols(),
        }
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        match self {
            DesignMatrix::Dense(m) => m.col_dot(j, v),
            DesignMatrix::Sparse(m) => m.col_dot(j, v),
        }
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]) {
        match self {
            DesignMatrix::Dense(m) => m.col_axpy(j, alpha, v),
            DesignMatrix::Sparse(m) => m.col_axpy(j, alpha, v),
        }
    }

    #[inline]
    fn col_sq_norm(&self, j: usize) -> f64 {
        match self {
            DesignMatrix::Dense(m) => m.col_sq_norm(j),
            DesignMatrix::Sparse(m) => m.col_sq_norm(j),
        }
    }

    fn gram(&self, i: usize, j: usize) -> f64 {
        match self {
            DesignMatrix::Dense(m) => m.gram(i, j),
            DesignMatrix::Sparse(m) => m.gram(i, j),
        }
    }

    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64 {
        match self {
            DesignMatrix::Dense(m) => m.gram_weighted(i, j, w),
            DesignMatrix::Sparse(m) => m.gram_weighted(i, j, w),
        }
    }

    fn density(&self) -> f64 {
        match self {
            DesignMatrix::Dense(m) => m.density(),
            DesignMatrix::Sparse(m) => m.density(),
        }
    }
}

/// A ready-to-fit problem: standardized design + response (+ ground
/// truth when synthetic).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub design: DesignMatrix,
    pub response: Vec<f64>,
    /// True coefficients when the data is simulated (for oracle checks).
    pub beta_true: Option<Vec<f64>>,
    pub loss: crate::loss::Loss,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.design.nrows()
    }

    pub fn p(&self) -> usize {
        self.design.ncols()
    }

    /// The dense design, or `None` for sparse datasets — the `.hxd`
    /// packer and the bench suites need raw column-major storage.
    pub fn dense_design(&self) -> Option<&DenseMatrix> {
        match &self.design {
            DesignMatrix::Dense(m) => Some(m),
            DesignMatrix::Sparse(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CscMatrix;

    #[test]
    fn enum_dispatch_matches_inner() {
        let sp = CscMatrix::from_triplets(3, 2, &[(0, 0, 1.0), (2, 1, 2.0)]);
        let d = sp.to_dense();
        let de = DesignMatrix::Dense(d.clone());
        let se = DesignMatrix::Sparse(sp);
        let v = vec![1.0, 2.0, 3.0];
        for j in 0..2 {
            assert_eq!(de.col_dot(j, &v), se.col_dot(j, &v));
            assert_eq!(de.col_sq_norm(j), se.col_sq_norm(j));
        }
        assert_eq!(de.nrows(), 3);
        assert!(se.is_sparse());
        assert!(!de.is_sparse());
        assert!((se.density() - 2.0 / 6.0).abs() < 1e-15);
        assert_eq!(de.density(), 1.0);
    }
}
