//! Bench: micro-kernels on the L3 hot path — dot, axpy, the full
//! correlation sweep (native and through the PJRT artifact when
//! available), a coordinate-descent epoch, and the Algorithm-1 sweep
//! update. This is the §Perf instrumentation (EXPERIMENTS.md).

use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::hessian::HessianTracker;
use hessian_screening::linalg::{blas, Design};
use hessian_screening::metrics::Summary;
use hessian_screening::rng::Xoshiro256pp;
use hessian_screening::runtime::RuntimeEngine;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> Summary {
    // warmup
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let s = Summary::of(&times);
    println!(
        "{name:<42} {:>12.3} µs  ± {:>8.3}",
        s.mean * 1e6,
        s.ci_half * 1e6
    );
    s
}

fn main() {
    let n = 200;
    let p = 20_000;
    let data = SyntheticSpec::new(n, p, 20).rho(0.4).seed(1).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let y = data.response.clone();
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let mut v = vec![0.0; n];
    rng.fill_gaussian(&mut v);

    println!("micro-kernels (n={n}, p={p})");
    let col = dense.col(17).to_vec();
    let mut acc = 0.0;
    bench("blas::dot (n=200)", 2_000, || {
        acc += blas::dot(&col, std::hint::black_box(&v));
    });
    let mut out = vec![0.0; n];
    bench("blas::axpy (n=200)", 2_000, || {
        blas::axpy(1.0001, &col, &mut out);
        std::hint::black_box(&out);
    });

    let mut c = vec![0.0; p];
    let sweep = bench("native full sweep X^T r (200x20000)", 50, || {
        for j in 0..p {
            c[j] = dense.col_dot(j, &v);
        }
        std::hint::black_box(&c);
    });
    // FLOP accounting: 2·n·p flops per sweep.
    let gflops = 2.0 * n as f64 * p as f64 / sweep.mean / 1e9;
    println!("  -> native sweep throughput: {gflops:.2} GFLOP/s");

    // Backend sweep: PJRT artifacts when built with `--features pjrt`
    // and `make artifacts`, the pure-Rust NativeBackend otherwise.
    let engine = match RuntimeEngine::load_default() {
        Ok(e) => e,
        Err(_) => {
            println!("(PJRT artifacts not built; benching the native backend)");
            RuntimeEngine::native()
        }
    };
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    let label = format!("{} xt_r backend sweep (200x20000)", engine.backend_name());
    bench(&label, 20, || {
        let _ = engine.correlation(&reg, &v).unwrap();
    });

    // CD epoch over a 100-predictor working set.
    let working: Vec<usize> = (0..100).collect();
    let mut beta = vec![0.0; p];
    let mut resid = y.clone();
    let norms: Vec<f64> = working.iter().map(|&j| dense.col_sq_norm(j)).collect();
    bench("CD epoch (|W|=100, n=200)", 500, || {
        for (k, &j) in working.iter().enumerate() {
            let g = dense.col_dot(j, &resid);
            let u = g + norms[k] * beta[j];
            let new = blas::soft_threshold(u, 50.0) / norms[k];
            if new != beta[j] {
                dense.col_axpy(j, beta[j] - new, &mut resid);
                beta[j] = new;
            }
        }
        std::hint::black_box(&resid);
    });

    // Algorithm-1 sweep update: enter 10 predictors into a 90-strong set.
    let base: Vec<usize> = (0..90).collect();
    let next: Vec<usize> = (0..100).collect();
    bench("Alg-1 sweep update (+10 into 90)", 50, || {
        let mut t = HessianTracker::new(n as f64 * 1e-4);
        t.rebuild(&dense, &base, None);
        t.update(&dense, &next, None);
    });
    let mut tr = HessianTracker::new(n as f64 * 1e-4);
    tr.rebuild(&dense, &base, None);
    bench("Alg-1 rebuild from scratch (|A|=100)", 50, || {
        let mut t = HessianTracker::new(n as f64 * 1e-4);
        t.rebuild(&dense, &next, None);
    });
    std::hint::black_box(acc);
}
