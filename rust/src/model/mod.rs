//! Fitted-model API: prediction, original-scale coefficients, scoring
//! and simple persistence — what a downstream user consumes after
//! fitting a path or a CV run.

use crate::data::{DesignMatrix, Standardization};
use crate::linalg::Design;
use crate::loss::Loss;
use crate::path::PathFit;

/// One selected model from a path: coefficients at a single λ, plus the
/// standardization needed to express them on the original data scale.
#[derive(Clone, Debug)]
pub struct FittedModel {
    pub loss: Loss,
    pub lambda: f64,
    /// Sparse coefficients on the *standardized* scale.
    pub coefs: Vec<(usize, f64)>,
    /// Present when the training data was standardized.
    pub standardization: Option<Standardization>,
    pub p: usize,
}

impl FittedModel {
    /// Extract step `k` of a path fit.
    pub fn from_path(fit: &PathFit, k: usize, p: usize, st: Option<Standardization>) -> Self {
        Self {
            loss: fit.loss,
            lambda: fit.lambdas[k],
            coefs: fit.betas[k].clone(),
            standardization: st,
            p,
        }
    }

    /// Linear predictor η for rows of a design on the *same scale* the
    /// model was fit on (standardized).
    pub fn linear_predictor(&self, design: &DesignMatrix) -> Vec<f64> {
        let mut eta = vec![0.0; design.nrows()];
        for &(j, b) in &self.coefs {
            design.col_axpy(j, b, &mut eta);
        }
        eta
    }

    /// Mean prediction μ(η) per row (identity / sigmoid / exp).
    pub fn predict(&self, design: &DesignMatrix) -> Vec<f64> {
        let y_shift = self
            .standardization
            .as_ref()
            .map(|s| s.y_mean)
            .unwrap_or(0.0);
        self.linear_predictor(design)
            .into_iter()
            .map(|e| self.loss.mu(e) + y_shift)
            .collect()
    }

    /// Hard class labels for logistic models.
    pub fn classify(&self, design: &DesignMatrix) -> Vec<u8> {
        assert!(matches!(self.loss, Loss::Logistic));
        self.linear_predictor(design)
            .into_iter()
            .map(|e| u8::from(e > 0.0))
            .collect()
    }

    /// Dense coefficients on the original (unstandardized) scale, with
    /// the intercept implied by centering.
    pub fn raw_coefficients(&self) -> (Vec<f64>, f64) {
        let mut dense = vec![0.0; self.p];
        for &(j, b) in &self.coefs {
            dense[j] = b;
        }
        match &self.standardization {
            Some(st) => st.unstandardize_coefs(&dense),
            None => (dense, 0.0),
        }
    }

    pub fn support(&self) -> Vec<usize> {
        self.coefs.iter().map(|&(j, _)| j).collect()
    }

    /// Mean deviance on (design, y) — the generic score.
    pub fn score_deviance(&self, design: &DesignMatrix, y: &[f64]) -> f64 {
        let eta = self.linear_predictor(design);
        self.loss.deviance(y, &eta) / y.len().max(1) as f64
    }

    /// Mean squared error (Gaussian convenience).
    pub fn score_mse(&self, design: &DesignMatrix, y: &[f64]) -> f64 {
        let eta = self.linear_predictor(design);
        eta.iter()
            .zip(y)
            .map(|(e, v)| (e - v) * (e - v))
            .sum::<f64>()
            / y.len().max(1) as f64
    }

    /// Classification accuracy (logistic convenience).
    pub fn score_accuracy(&self, design: &DesignMatrix, y: &[f64]) -> f64 {
        let labels = self.classify(design);
        labels
            .iter()
            .zip(y)
            .filter(|(&l, &t)| (l as f64 - t).abs() < 0.5)
            .count() as f64
            / y.len().max(1) as f64
    }

    /// Serialize to a simple TSV: `j \t beta_j` lines with a header.
    pub fn to_tsv(&self) -> String {
        let mut out = format!(
            "# loss={:?} lambda={} p={}\n",
            self.loss, self.lambda, self.p
        );
        for &(j, b) in &self.coefs {
            out.push_str(&format!("{j}\t{b:.17e}\n"));
        }
        out
    }

    /// Parse the TSV produced by [`Self::to_tsv`].
    pub fn from_tsv(text: &str) -> Result<Self, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty input")?;
        let mut loss = Loss::Gaussian;
        let mut lambda = 0.0;
        let mut p = 0usize;
        for tok in header.trim_start_matches('#').split_whitespace() {
            if let Some(v) = tok.strip_prefix("loss=") {
                loss = match v {
                    "Gaussian" => Loss::Gaussian,
                    "Logistic" => Loss::Logistic,
                    "Poisson" => Loss::Poisson,
                    other => return Err(format!("unknown loss {other}")),
                };
            } else if let Some(v) = tok.strip_prefix("lambda=") {
                lambda = v.parse().map_err(|_| "bad lambda")?;
            } else if let Some(v) = tok.strip_prefix("p=") {
                p = v.parse().map_err(|_| "bad p")?;
            }
        }
        let mut coefs = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split('\t');
            let j: usize = it
                .next()
                .ok_or("missing index")?
                .parse()
                .map_err(|_| "bad index")?;
            let b: f64 = it
                .next()
                .ok_or("missing value")?
                .parse()
                .map_err(|_| "bad value")?;
            coefs.push((j, b));
        }
        Ok(Self {
            loss,
            lambda,
            coefs,
            standardization: None,
            p,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::path::PathFitter;
    use crate::screening::ScreeningKind;

    fn fitted() -> (crate::data::Dataset, FittedModel) {
        let data = SyntheticSpec::new(100, 30, 4).snr(5.0).seed(2).generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        let k = fit.lambdas.len() / 2;
        let m = FittedModel::from_path(&fit, k, 30, None);
        (data, m)
    }

    #[test]
    fn predictions_reduce_mse_vs_null() {
        let (data, m) = fitted();
        let mse = m.score_mse(&data.design, &data.response);
        let null_mse = data.response.iter().map(|v| v * v).sum::<f64>()
            / data.response.len() as f64;
        assert!(mse < 0.7 * null_mse, "mse {mse} vs null {null_mse}");
        assert!(m.score_deviance(&data.design, &data.response) < 1.01 * null_mse);
    }

    #[test]
    fn logistic_classification_beats_chance() {
        let data = SyntheticSpec::new(200, 20, 3)
            .loss(Loss::Logistic)
            .signal_scale(1.5)
            .seed(3)
            .generate();
        let fit = PathFitter::new(Loss::Logistic, ScreeningKind::Working)
            .fit(&data.design, &data.response);
        let m = FittedModel::from_path(&fit, fit.lambdas.len() - 1, 20, None);
        let acc = m.score_accuracy(&data.design, &data.response);
        assert!(acc > 0.65, "accuracy {acc}");
        let probs = m.predict(&data.design);
        assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn tsv_roundtrip() {
        let (_, m) = fitted();
        let text = m.to_tsv();
        let m2 = FittedModel::from_tsv(&text).unwrap();
        assert_eq!(m.coefs, m2.coefs);
        assert_eq!(m.p, m2.p);
        assert!((m.lambda - m2.lambda).abs() < 1e-12);
        assert_eq!(m.loss, m2.loss);
    }

    #[test]
    fn from_tsv_rejects_garbage() {
        assert!(FittedModel::from_tsv("").is_err());
        assert!(FittedModel::from_tsv("# loss=Banana lambda=1 p=2\n").is_err());
        assert!(FittedModel::from_tsv("# loss=Gaussian lambda=1 p=2\nxx\t1.0\n").is_err());
    }

    #[test]
    fn support_and_raw_coefs() {
        let (_, m) = fitted();
        let support = m.support();
        assert!(!support.is_empty());
        let (raw, intercept) = m.raw_coefficients();
        assert_eq!(raw.len(), 30);
        assert_eq!(intercept, 0.0); // no standardization recorded
        for &j in &support {
            assert_ne!(raw[j], 0.0);
        }
    }
}
