//! Build-time shim for the `xla` crate's PJRT surface.
//!
//! The offline build environment has neither the `xla` crate nor an
//! XLA/PJRT shared library, so the PJRT engine (`runtime::pjrt`)
//! type-checks against this API-compatible stub instead: the types and
//! signatures mirror the subset of `xla` 0.1.x the engine uses, and
//! every runtime entry point returns a descriptive error. Swapping the
//! stub for the real crate is a one-line import change in
//! `runtime/pjrt.rs` plus a `Cargo.toml` dependency — no engine code
//! changes — which keeps `cargo check --features pjrt` meaningful as a
//! type-level regression gate for the artifact path.

#![allow(dead_code)]

use std::fmt;

/// Stub error carrying the reason the runtime path is unavailable.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT runtime not linked (this build uses the in-tree \
         xla_stub; see README \"Feature matrix\")"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host-side literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), XlaError> {
        unavailable("Literal::to_tuple3")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}
