//! Penalty extensions (paper §5, "Other interesting directions"):
//! elastic net (§3.3.6), and the non-convex SCAD and MCP penalties.
//!
//! The paper notes that SCAD/MCP are "locally convex for intervals of
//! the regularization path (Breheny & Huang 2011), which enables the
//! use of our method". We implement the penalties through their
//! coordinate-wise proximal/thresholding operators — the exact form
//! used by `ncvreg`-style coordinate descent — and expose an
//! experimental path fitter that runs the working-set strategy with
//! these operators. (The Hessian *screening* estimate stays based on
//! the ℓ₁ KKT system; for SCAD/MCP it acts as a heuristic working-set
//! proposal, checked by the same KKT machinery.)

use crate::linalg::blas::soft_threshold;

/// Penalty family for the coordinate-wise update.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Penalty {
    /// λ‖β‖₁.
    L1,
    /// λ‖β‖₁ + φ‖β‖₂²/2.
    ElasticNet { phi: f64 },
    /// Smoothly Clipped Absolute Deviation (Fan & Li 2001), a > 2.
    Scad { a: f64 },
    /// Minimax Concave Penalty (Zhang 2010), gamma > 1.
    Mcp { gamma: f64 },
}

impl Penalty {
    /// Coordinate-wise minimizer of ½v(β − z/v)² + pen(β; λ) where `z`
    /// is the unpenalized coordinate update scaled by the curvature `v`
    /// (i.e. z = xⱼᵀr + v·βⱼ in the CD loop). For L1 this is
    /// S(z, λ)/v; for the non-convex penalties the closed forms are the
    /// standard ncvreg expressions (assuming standardized predictors,
    /// where v is the Hessian diagonal).
    pub fn prox(self, z: f64, v: f64, lambda: f64) -> f64 {
        debug_assert!(v > 0.0);
        match self {
            Penalty::L1 => soft_threshold(z, lambda) / v,
            Penalty::ElasticNet { phi } => soft_threshold(z, lambda) / (v + phi),
            Penalty::Scad { a } => {
                debug_assert!(a > 2.0, "SCAD needs a > 2");
                // Solutions by region of |z|/v (Fan & Li; ncvreg eq. 5).
                let abs = z.abs() / v;
                if abs <= lambda / v + lambda {
                    soft_threshold(z, lambda) / v
                } else if abs <= a * lambda {
                    // middle region: shrink toward the SCAD taper
                    let t = soft_threshold(z, a * lambda / (a - 1.0));
                    t / (v - 1.0 / (a - 1.0))
                } else {
                    z / v
                }
            }
            Penalty::Mcp { gamma } => {
                debug_assert!(gamma > 1.0, "MCP needs gamma > 1");
                let abs = z.abs() / v;
                if abs <= gamma * lambda {
                    soft_threshold(z, lambda) / (v - 1.0 / gamma)
                } else {
                    z / v
                }
            }
        }
    }

    /// Penalty value for a single coordinate (used in objective checks).
    pub fn value(self, beta: f64, lambda: f64) -> f64 {
        let b = beta.abs();
        match self {
            Penalty::L1 => lambda * b,
            Penalty::ElasticNet { phi } => lambda * b + 0.5 * phi * beta * beta,
            Penalty::Scad { a } => {
                if b <= lambda {
                    lambda * b
                } else if b <= a * lambda {
                    (2.0 * a * lambda * b - b * b - lambda * lambda) / (2.0 * (a - 1.0))
                } else {
                    lambda * lambda * (a + 1.0) / 2.0
                }
            }
            Penalty::Mcp { gamma } => {
                if b <= gamma * lambda {
                    lambda * b - b * b / (2.0 * gamma)
                } else {
                    0.5 * gamma * lambda * lambda
                }
            }
        }
    }

    /// Derivative of the penalty w.r.t. |β| (the effective threshold in
    /// KKT checks — for L1 it is the constant λ).
    pub fn derivative(self, beta_abs: f64, lambda: f64) -> f64 {
        match self {
            Penalty::L1 => lambda,
            Penalty::ElasticNet { .. } => lambda, // the φ part is smooth
            Penalty::Scad { a } => {
                if beta_abs <= lambda {
                    lambda
                } else if beta_abs <= a * lambda {
                    (a * lambda - beta_abs) / (a - 1.0)
                } else {
                    0.0
                }
            }
            Penalty::Mcp { gamma } => (lambda - beta_abs / gamma).max(0.0),
        }
    }

    /// Is the coordinate objective convex for curvature `v`? (SCAD/MCP
    /// are coordinate-convex when v exceeds the concavity; Breheny &
    /// Huang's condition.)
    pub fn coordinate_convex(self, v: f64) -> bool {
        match self {
            Penalty::L1 | Penalty::ElasticNet { .. } => true,
            Penalty::Scad { a } => v > 1.0 / (a - 1.0),
            Penalty::Mcp { gamma } => v > 1.0 / gamma,
        }
    }
}

/// Pathwise CD for the penalized least-squares problem with an
/// arbitrary [`Penalty`] — the experimental §5 extension. Uses the
/// ever-active working-set strategy with full KKT sweeps (the
/// generalized KKT threshold is the penalty derivative at |βⱼ|).
pub mod path {
    use super::Penalty;
    use crate::linalg::Design;
    use crate::rng::Xoshiro256pp;

    pub struct NcvFit {
        pub lambdas: Vec<f64>,
        pub betas: Vec<Vec<(usize, f64)>>,
    }

    /// Fit a SCAD/MCP/enet lasso-style path (Gaussian loss).
    pub fn fit_ncv<D: Design + ?Sized>(
        design: &D,
        y: &[f64],
        penalty: Penalty,
        path_length: usize,
        lambda_min_ratio: f64,
        seed: u64,
    ) -> NcvFit {
        let n = design.nrows();
        let p = design.ncols();
        let norms: Vec<f64> = (0..p).map(|j| design.col_sq_norm(j) / n as f64).collect();
        let mut resid = y.to_vec();
        let lmax = (0..p)
            .map(|j| design.col_dot(j, &resid).abs() / n as f64)
            .fold(0.0f64, f64::max);
        let lambdas = crate::path::lambda_grid(lmax, lambda_min_ratio, path_length);
        let mut beta = vec![0.0; p];
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut working: Vec<usize> = Vec::new();
        let mut out = NcvFit {
            lambdas: lambdas.clone(),
            betas: vec![Vec::new()],
        };
        for &lambda in &lambdas[1..] {
            loop {
                // CD passes on the working set until coefficient moves
                // are tiny (non-convex ⇒ no duality gap; ncvreg uses the
                // same criterion).
                for _ in 0..10_000 {
                    let mut max_move = 0.0f64;
                    rng.shuffle(&mut working);
                    for &j in &working {
                        let v = norms[j];
                        if v <= 0.0 {
                            continue;
                        }
                        let bj = beta[j];
                        let z = design.col_dot(j, &resid) / n as f64 + v * bj;
                        let new = penalty.prox(z, v, lambda);
                        if new != bj {
                            design.col_axpy(j, (bj - new) * 1.0, &mut resid);
                            beta[j] = new;
                            max_move = max_move.max((new - bj).abs());
                        }
                    }
                    if max_move < 1e-8 {
                        break;
                    }
                }
                // Generalized KKT sweep: violation when |xⱼᵀr|/n exceeds
                // the penalty derivative at |βⱼ|.
                let mut violations = Vec::new();
                for j in 0..p {
                    if beta[j] != 0.0 || working.contains(&j) {
                        continue;
                    }
                    let c = design.col_dot(j, &resid).abs() / n as f64;
                    if c > penalty.derivative(0.0, lambda) {
                        violations.push(j);
                    }
                }
                if violations.is_empty() {
                    break;
                }
                working.extend(violations);
            }
            working = (0..p).filter(|&j| beta[j] != 0.0).collect();
            out.betas
                .push(working.iter().map(|&j| (j, beta[j])).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_prox_is_soft_threshold() {
        assert_eq!(Penalty::L1.prox(3.0, 1.0, 1.0), 2.0);
        assert_eq!(Penalty::L1.prox(-0.5, 1.0, 1.0), 0.0);
        assert_eq!(Penalty::L1.prox(4.0, 2.0, 1.0), 1.5);
    }

    #[test]
    fn elastic_net_shrinks_more_than_l1() {
        let l1 = Penalty::L1.prox(3.0, 1.0, 1.0);
        let en = Penalty::ElasticNet { phi: 1.0 }.prox(3.0, 1.0, 1.0);
        assert!(en < l1);
        assert!(en > 0.0);
    }

    #[test]
    fn scad_unbiased_for_large_signals() {
        // |z| > aλ ⇒ no shrinkage (the oracle property's mechanism).
        let p = Penalty::Scad { a: 3.7 };
        assert_eq!(p.prox(10.0, 1.0, 1.0), 10.0);
        // small signals: same as lasso
        assert_eq!(p.prox(1.5, 1.0, 1.0), soft_threshold(1.5, 1.0));
        // continuity between regions (approximately)
        let z1 = 2.0 - 1e-9;
        let z2 = 2.0 + 1e-9;
        assert!((p.prox(z1, 1.0, 1.0) - p.prox(z2, 1.0, 1.0)).abs() < 1e-6);
    }

    #[test]
    fn mcp_unbiased_for_large_signals() {
        let p = Penalty::Mcp { gamma: 3.0 };
        assert_eq!(p.prox(5.0, 1.0, 1.0), 5.0);
        let inside = p.prox(2.0, 1.0, 1.0);
        // firm threshold: between lasso and OLS
        assert!(inside > soft_threshold(2.0, 1.0));
        assert!(inside < 2.0);
    }

    #[test]
    fn penalty_values_continuous_at_boundaries() {
        let lam = 0.7;
        for pen in [Penalty::Scad { a: 3.7 }, Penalty::Mcp { gamma: 3.0 }] {
            let boundary = match pen {
                Penalty::Scad { a } => a * lam,
                Penalty::Mcp { gamma } => gamma * lam,
                _ => unreachable!(),
            };
            let v1 = pen.value(boundary - 1e-9, lam);
            let v2 = pen.value(boundary + 1e-9, lam);
            assert!((v1 - v2).abs() < 1e-6, "{pen:?} discontinuous");
            // beyond the boundary the penalty is constant
            assert!((pen.value(boundary + 5.0, lam) - v2).abs() < 1e-12);
        }
    }

    #[test]
    fn derivative_tapers_to_zero() {
        let scad = Penalty::Scad { a: 3.7 };
        let mcp = Penalty::Mcp { gamma: 3.0 };
        assert_eq!(scad.derivative(0.0, 1.0), 1.0);
        assert_eq!(scad.derivative(10.0, 1.0), 0.0);
        assert_eq!(mcp.derivative(0.0, 1.0), 1.0);
        assert_eq!(mcp.derivative(10.0, 1.0), 0.0);
        // monotone non-increasing
        let mut prev = f64::INFINITY;
        for k in 0..40 {
            let d = mcp.derivative(k as f64 * 0.1, 1.0);
            assert!(d <= prev + 1e-12);
            prev = d;
        }
    }

    #[test]
    fn coordinate_convexity_conditions() {
        assert!(Penalty::L1.coordinate_convex(0.1));
        assert!(Penalty::Scad { a: 3.7 }.coordinate_convex(1.0));
        assert!(!Penalty::Scad { a: 3.7 }.coordinate_convex(0.2));
        assert!(Penalty::Mcp { gamma: 3.0 }.coordinate_convex(1.0));
        assert!(!Penalty::Mcp { gamma: 3.0 }.coordinate_convex(0.3));
    }

    #[test]
    fn ncv_path_mcp_debiases_strong_signal() {
        // Deterministic check of the §5 extension: a single strong
        // predictor. The lasso estimate is biased downward by ~λ/v;
        // MCP (firm thresholding) returns the unpenalized estimate once
        // |z| > γλ — the mechanism behind its oracle property.
        use crate::data::{DesignMatrix, SyntheticSpec};
        let data = SyntheticSpec::new(400, 5, 1).snr(50.0).seed(6).generate();
        let truth = data.beta_true.as_ref().unwrap();
        let j_true = truth.iter().position(|&t| t != 0.0).unwrap();
        let design: &DesignMatrix = &data.design;
        let lasso = path::fit_ncv(design, &data.response, Penalty::L1, 20, 1e-2, 0);
        let mcp = path::fit_ncv(
            design,
            &data.response,
            Penalty::Mcp { gamma: 3.0 },
            20,
            1e-2,
            0,
        );
        // Compare at a mid-path λ where the signal is active for both.
        let k = 10;
        let coef = |fit: &path::NcvFit| {
            fit.betas[k]
                .iter()
                .find(|&&(j, _)| j == j_true)
                .map(|&(_, v)| v)
                .unwrap_or(0.0)
        };
        let bl = coef(&lasso);
        let bm = coef(&mcp);
        assert!(bl > 0.0 && bm > 0.0, "signal inactive: lasso {bl} mcp {bm}");
        // MCP estimate strictly larger (less biased) than the lasso's.
        assert!(bm > bl, "mcp {bm} not debiased vs lasso {bl}");
    }
}
