//! # hessian-screening
//!
//! A production-grade reproduction of **“The Hessian Screening Rule”**
//! (Johan Larsson & Jonas Wallin, NeurIPS 2022): pathwise ℓ₁-regularized
//! GLM solving (lasso, logistic, Poisson) with the paper's second-order
//! sequential screening rule, sweep-operator Hessian updates, Hessian
//! warm starts, and re-implementations of every baseline the paper
//! compares against (Strong rule, working(+) sets, Celer, Blitz,
//! Gap Safe, EDPP, Dynamic Sasvi).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: path driver (paper Alg. 2),
//!   coordinate-descent solver, screening rules, Hessian machinery,
//!   data substrate, experiment harness, CLI.
//! * **L2 (python/compile/model.py)** — JAX formulations of the numeric
//!   hot spots (correlation sweep Xᵀr, weighted Gram blocks), AOT-lowered
//!   to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels backing L2,
//!   validated against a pure-jnp oracle.
//!
//! The [`runtime`] module hides the execution substrate behind a
//! [`runtime::Backend`]: the default build ships the pure-Rust
//! [`runtime::NativeBackend`] (zero dependencies, f64-exact), and the
//! non-default `pjrt` cargo feature compiles the AOT/PJRT engine that
//! loads the L2 artifacts so the solve path never touches Python. See
//! the README's feature matrix.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hessian_screening::prelude::*;
//!
//! // Simulate a small lasso problem (n=100, p=50, 5 true signals).
//! let data = SyntheticSpec::new(100, 50, 5)
//!     .rho(0.4)
//!     .snr(2.0)
//!     .seed(42)
//!     .generate();
//!
//! // Fit a full regularization path with the Hessian screening rule.
//! let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
//!     .fit(&data.design, &data.response);
//! assert!(fit.lambdas.len() > 1);
//! ```

// `unsafe` hygiene: the only unsafe in the crate is the bounds-check
// elision in `linalg/{blas,dense,sparse}.rs`; every block carries a
// `// SAFETY:` comment (enforced by `cargo run -p xtask -- lint`) and
// any future `unsafe fn` must spell out its internal unsafety.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod error;
pub mod experiments;
pub mod hessian;
#[cfg(feature = "paranoid")]
pub mod invariants;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod model;
pub mod path;
pub mod penalty;
pub mod runtime;
pub mod screening;
pub mod solver;
pub mod storage;
pub mod rng;
pub mod testkit;

/// Convenient re-exports of the main user-facing types.
pub mod prelude {
    pub use crate::data::{standardize, Dataset, DesignMatrix, SyntheticSpec};
    pub use crate::linalg::{CscMatrix, DenseMatrix, Design};
    pub use crate::loss::Loss;
    pub use crate::path::{PathFit, PathFitter, PathSettings};
    pub use crate::screening::ScreeningKind;
}
