//! Linear-algebra substrate.
//!
//! The image has no BLAS/LAPACK bindings and no `ndarray`/`nalgebra`
//! crates offline, so this module implements the dense and sparse
//! primitives the solver needs, tuned for the access patterns of
//! pathwise coordinate descent:
//!
//! * [`dense::DenseMatrix`] — column-major storage so that coordinate
//!   descent and correlation sweeps touch contiguous memory.
//! * [`blas`] — unrolled dot/axpy/nrm2 micro-kernels (the L3 hot path).
//! * [`sparse::CscMatrix`] — compressed sparse column designs (the
//!   paper's e2006/news20/rcv1 analogues).
//! * [`cholesky`] — positive-definite factorization/solves used by the
//!   sweep-operator updates of the Hessian inverse.
//! * [`eigen`] — cyclic Jacobi symmetric eigendecomposition for the
//!   Hessian preconditioner (paper Appendix C).

pub mod blas;
pub mod cholesky;
pub mod dense;
pub mod eigen;
pub mod sparse;

pub use cholesky::Cholesky;
pub use dense::DenseMatrix;
pub use sparse::CscMatrix;

/// A design matrix abstraction: everything the solver, screening rules
/// and Hessian updates need from X, implemented for both dense and
/// sparse storage. Columns are assumed standardized by the data layer;
/// `col_dot_*` operate on the stored (already standardized) values.
pub trait Design: Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;

    /// xⱼᵀ v for a dense vector v of length n.
    fn col_dot(&self, j: usize, v: &[f64]) -> f64;

    /// v ← v + alpha * xⱼ.
    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]);

    /// ‖xⱼ‖₂².
    fn col_sq_norm(&self, j: usize) -> f64;

    /// out ← Xᵀ v (full correlation sweep; the screening hot spot).
    fn t_gemv(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.nrows());
        assert_eq!(out.len(), self.ncols());
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.col_dot(j, v);
        }
    }

    /// out ← Xᵀ v restricted to `cols`; out[i] corresponds to cols[i].
    fn t_gemv_subset(&self, v: &[f64], cols: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = self.col_dot(j, v);
        }
    }

    /// out ← X_cols · beta where beta[i] multiplies column cols[i].
    fn gemv_subset(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), beta.len());
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for (&j, &b) in cols.iter().zip(beta) {
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    /// Gram entry xᵢᵀ xⱼ.
    fn gram(&self, i: usize, j: usize) -> f64;

    /// Weighted column dot: Σ_r w_r x_{ri} x_{rj}; `w = None` means unit
    /// weights. Used when forming GLM Hessian blocks X_AᵀD(w)X_A.
    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64;

    /// Fraction of structurally non-zero entries.
    fn density(&self) -> f64;
}
