//! Bench: Figure 3 — full-path timing on the simulated scenarios
//! (the paper's headline benchmark), plus Figure 2 warm starts.

use hessian_screening::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        reps: 3,
        ..Default::default()
    };
    experiments::run_experiment("fig3", &cfg).expect("fig3");
    experiments::run_experiment("fig2", &cfg).expect("fig2");
}
