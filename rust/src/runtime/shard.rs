//! Column-sharded compute backend with pipelined shard uploads.
//!
//! [`ShardedBackend`] splits a registered design into contiguous
//! *column shards*, each held by its own inner [`Backend`] handle — N
//! independent [`NativeBackend`] engines today, PJRT devices once the
//! `pjrt` feature carries a real multi-device client
//! ([`ShardedBackend::from_backends`] accepts any engine set). A
//! reduction layer merges the per-shard results back into the exact
//! global answers the path driver expects:
//!
//! * `correlation` / `kkt_sweep` — per-shard correlation slices are
//!   concatenated in shard order; every entry is produced by the same
//!   per-column kernel the unsharded backend runs, so the merged
//!   vector is **bit-identical** to the unsharded sweep.
//! * `kkt_sweep_batch` — per-shard batches are concatenated and the
//!   Gap-Safe keep-masks are **rebuilt from the global correlation
//!   vector**: a shard only knows its local sup-norm, and a mask built
//!   from a shard-local ‖Xᵀr‖∞ would be unsound. The rebuilt masks
//!   match the unsharded [`NativeBackend::kkt_sweep_batch`] bit for
//!   bit (same dual scale, same gap, same sphere test).
//! * `gram_block` — panel rows are fanned out across the shard
//!   engines and concatenated row-major; each row is computed by the
//!   same scalar kernel regardless of the split.
//!
//! **Pipelined uploads.** Registration is a double-buffered async
//! pipeline (`std::thread` + `sync_channel(1)`, zero dependencies):
//! shard 0 is staged and uploaded synchronously so the caller can
//! start sweeping immediately, then a background thread stages shard
//! k+1's column panel while shard k uploads — and while the caller
//! sweeps the shards that are already resident. Sweeps block per
//! shard (condvar) only until that shard's upload lands, so the first
//! full sweep overlaps the tail of the upload pipeline. The overlap
//! is *observable*, not assumed: [`UploadStats`] counts staged and
//! uploaded panels, how many were already staged when the uploader
//! asked (i.e. staging fully overlapped other work), the seconds the
//! uploader stalled waiting on staging, plus the bytes/seconds of
//! source reads and the in-flight panel byte gauge; the path driver
//! snapshots it into `StepStats::{shards, upload_overlap}`.
//!
//! **Out-of-core staging.** The stager pulls panels through the
//! [`ColumnSource`] seam (`crate::storage`), never from a borrowed
//! resident slice: `register_design` wraps its input in a
//! [`ResidentSource`], while `register_source` accepts any source —
//! in particular an `HxdSource` streaming a checksummed `.hxd` file,
//! so shard k+1 is staged *from disk* while shard k uploads. A source
//! read that fails mid-stream fails that shard's slot (and every
//! later one) with the underlying error — a sweep returns a
//! descriptive `Err`, it never hangs.
//!
//! Memory math: at most two staged panels (2·np/k f64) are alive
//! while the per-shard engines take ownership of their slices —
//! enforced by the `inflight_bytes`/`peak_inflight_bytes` gauges, not
//! hoped for. On top of that the resident path holds the caller's
//! copy (np) inside its `ResidentSource` (peak ≈ np·(2 + 2/k) beyond
//! the caller's own buffer is thus down to ≈ np·(1 + 2/k)), while the
//! `.hxd` path holds only a one-block read cache (n·block_cols), so
//! its peak is ≈ np·(1 + 2/k) *total* — the design itself never
//! exists in one allocation. See README "Out-of-core designs".

#![forbid(unsafe_code)]

use super::{Backend, DesignRepr, KktBatch, NativeBackend, RegisteredDesign};
use crate::error::Result;
use crate::linalg::{blas, Design};
use crate::loss::Loss;
use crate::storage::{ColumnSource, ResidentSource};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// ⌈a/b⌉ (usize::div_ceil needs Rust 1.73; MSRV is 1.70).
fn div_ceil(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

/// Lock a mutex, recovering from poisoning. Every mutex in this module
/// guards plain bookkeeping (counters, slot states, a join handle)
/// that stays consistent even if a holder panicked mid-update.
/// Recovering matters for liveness: if a stager panic poisoned the
/// stats lock and the uploader then panicked on `lock().unwrap()`, the
/// trailing fail-loop would never run, slots would stay `Pending`, and
/// every sweep waiter would hang forever.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Best-effort panic payload → message, for surfacing a stager panic
/// in the slot failure handed to sweep waiters.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Diagnostics/test hook run by the stager thread right before it
/// stages pipelined panel `k` (shard 0 is staged synchronously by
/// `register_design` and never sees the hook). Used to inject delays
/// (stall bookkeeping tests, `HX_STAGE_DELAY_MS`) and failures
/// (stager-panic tests).
pub type StageHook = Arc<dyn Fn(usize) + Send + Sync>;

/// A hook that sleeps `ms` per panel — the slow-stager injection
/// behind `HX_STAGE_DELAY_MS`.
fn delay_hook(ms: u64) -> StageHook {
    Arc::new(move |_k| std::thread::sleep(std::time::Duration::from_millis(ms)))
}

/// `HX_STAGE_DELAY_MS=<ms>` injects a slow stager into every upload
/// pipeline of sharded backends constructed afterwards.
fn stage_hook_from_env() -> Option<StageHook> {
    std::env::var("HX_STAGE_DELAY_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(delay_hook)
}

/// Pipeline counters for the double-buffered shard upload path.
/// Cumulative per backend (a backend can register several designs).
#[derive(Clone, Debug, Default)]
pub struct UploadStats {
    /// Shard panels staged (host-side contiguous column-slice copies).
    pub staged: usize,
    /// Shard panels registered with ("uploaded to") their engine.
    pub uploaded: usize,
    /// Uploads whose panel was already staged when the uploader asked
    /// for it — staging fully overlapped the previous shard's upload
    /// (or the caller's sweeps on already-resident shards).
    pub overlapped: usize,
    /// Wall-seconds spent staging panels.
    pub stage_seconds: f64,
    /// Wall-seconds spent in the inner engines' `register_design`.
    pub upload_seconds: f64,
    /// Wall-seconds the uploader stalled waiting for a staged panel.
    pub stall_seconds: f64,
    /// Column-data bytes pulled from the registration source (file
    /// reads for an `.hxd` source, resident copies otherwise).
    pub bytes_read: u64,
    /// Wall-seconds spent inside `ColumnSource::read_cols`. A subset
    /// of `stage_seconds` (staging currently *is* the read).
    pub read_seconds: f64,
    /// Bytes of panels staged but not yet taken by the uploader — the
    /// live double-buffer gauge. Zero once a pipeline is quiescent.
    pub inflight_bytes: u64,
    /// High-water mark of `inflight_bytes`: the memory-bound proof.
    /// Never exceeds two panels (`2 × max_panel_bytes`).
    pub peak_inflight_bytes: u64,
    /// Largest single staged panel in bytes — on the streaming path
    /// this stays at `n·ceil(p/k)·8`, never the full design.
    pub max_panel_bytes: u64,
}

/// Contiguous column ranges `[start, end)`, one per shard; the final
/// shard is ragged when `p % shards != 0`, and trailing shards are
/// empty when `shards > p`.
fn shard_bounds(p: usize, shards: usize) -> Vec<(usize, usize)> {
    let chunk = div_ceil(p.max(1), shards);
    (0..shards)
        .map(|k| ((k * chunk).min(p), ((k + 1) * chunk).min(p)))
        .collect()
}

enum SlotState {
    Pending,
    Ready,
    Failed(String),
}

/// One shard's upload rendezvous: the pipeline thread fulfills it, the
/// sweep workers block on it until the shard is resident.
struct ShardSlot {
    state: Mutex<SlotState>,
    ready: Condvar,
    cell: OnceLock<RegisteredDesign>,
}

impl ShardSlot {
    fn new() -> Self {
        Self {
            state: Mutex::new(SlotState::Pending),
            ready: Condvar::new(),
            cell: OnceLock::new(),
        }
    }

    fn fulfill(&self, reg: RegisteredDesign) {
        // The cell is populated before the state flips to `Ready`, and
        // readers only observe the state under the mutex — the
        // release/acquire pairing on the state lock makes the cell
        // write visible to every reader that sees `Ready`.
        let _ = self.cell.set(reg);
        *lock_ignore_poison(&self.state) = SlotState::Ready;
        self.ready.notify_all();
    }

    fn fail(&self, msg: String) {
        let mut st = lock_ignore_poison(&self.state);
        if matches!(*st, SlotState::Pending) {
            *st = SlotState::Failed(msg);
        }
        drop(st);
        self.ready.notify_all();
    }

    /// Block until the shard's upload lands (or failed).
    fn wait(&self) -> Result<&RegisteredDesign> {
        let mut st = lock_ignore_poison(&self.state);
        while matches!(*st, SlotState::Pending) {
            st = self.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        match &*st {
            SlotState::Ready => Ok(self.cell.get().expect("ready slot holds a design")),
            SlotState::Failed(m) => Err(crate::err!("shard upload failed: {m}")),
            SlotState::Pending => unreachable!(),
        }
    }
}

/// The sharded representation held inside a [`RegisteredDesign`]: one
/// upload slot per shard (aligned with the backend's engines) plus the
/// background pipeline handle.
pub(crate) struct ShardedRepr {
    slots: Arc<Vec<ShardSlot>>,
    /// Background upload pipeline; joined on drop so no thread
    /// outlives the design it uploads.
    uploader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for ShardedRepr {
    fn drop(&mut self) {
        if let Some(h) = lock_ignore_poison(&self.uploader).take() {
            let _ = h.join();
        }
    }
}

/// A [`Backend`] that routes every design-bound op through contiguous
/// column shards, each owned by its own inner engine. See the module
/// docs for the reduction and pipelining contracts.
pub struct ShardedBackend {
    engines: Arc<Vec<Box<dyn Backend>>>,
    stats: Arc<Mutex<UploadStats>>,
    /// Optional stager-thread hook (delay/failure injection); seeded
    /// from `HX_STAGE_DELAY_MS` at construction.
    stage_hook: Option<StageHook>,
}

impl ShardedBackend {
    /// `shards` native engines with `threads_per_shard` worker threads
    /// each (both clamped to at least 1, so total workers =
    /// shards × threads_per_shard).
    pub fn native(shards: usize, threads_per_shard: usize) -> Self {
        let shards = shards.max(1);
        Self::from_backends(
            (0..shards)
                .map(|_| Box::new(NativeBackend::new(threads_per_shard.max(1))) as Box<dyn Backend>)
                .collect(),
        )
    }

    /// Wrap an explicit engine set — one shard per engine. This is the
    /// seam where PJRT devices plug in: hand one `PjrtBackend` per
    /// device and the column fan-out plus the mask reduction come for
    /// free.
    pub fn from_backends(engines: Vec<Box<dyn Backend>>) -> Self {
        assert!(!engines.is_empty(), "at least one shard engine required");
        Self {
            engines: Arc::new(engines),
            stats: Arc::new(Mutex::new(UploadStats::default())),
            stage_hook: stage_hook_from_env(),
        }
    }

    /// Replace the stager hook (tests: delay and panic injection). The
    /// hook runs in the stager thread right before each pipelined
    /// panel is staged.
    pub fn with_stage_hook(mut self, hook: StageHook) -> Self {
        self.stage_hook = Some(hook);
        self
    }

    fn repr<'d>(design: &'d RegisteredDesign) -> Result<&'d ShardedRepr> {
        match &design.repr {
            DesignRepr::Sharded(rep) => Ok(rep),
            _ => Err(crate::err!(
                "design was registered with a different backend"
            )),
        }
    }

    /// Run `f(shard, shard_design)` on every shard concurrently (each
    /// shard on its own engine), blocking per shard until its upload
    /// lands. Results come back in shard order; any `Err` propagates,
    /// any `None` (missing kernel) makes the whole op unavailable.
    fn shard_map<T, F>(&self, rep: &ShardedRepr, f: F) -> Result<Option<Vec<T>>>
    where
        T: Send,
        F: Fn(usize, &RegisteredDesign) -> Result<Option<T>> + Sync,
    {
        let k = rep.slots.len();
        let results: Vec<Result<Option<T>>> = if k == 1 {
            vec![rep.slots[0].wait().and_then(|reg| f(0, reg))]
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..k)
                    .map(|i| {
                        let f = &f;
                        let slots = &rep.slots;
                        s.spawn(move || slots[i].wait().and_then(|reg| f(i, reg)))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard sweep worker panicked"))
                    .collect()
            })
        };
        let mut vals = Vec::with_capacity(k);
        for r in results {
            match r? {
                Some(v) => vals.push(v),
                None => return Ok(None),
            }
        }
        Ok(Some(vals))
    }

    /// Paranoid spot check: recompute up to 8 evenly spaced entries of
    /// a merged correlation vector with a serial `blas::dot` on the
    /// resident shard panels and demand bitwise equality. Shards whose
    /// inner engine keeps no host-side column copy (non-`Native`
    /// representations) are skipped.
    #[cfg(feature = "paranoid")]
    fn spot_check_correlation(&self, rep: &ShardedRepr, c: &[f64], r: &[f64], p: usize) {
        let bounds = shard_bounds(p, self.engines.len());
        let n = r.len();
        let step = (p / 8).max(1);
        let mut j = 0;
        while j < p {
            let (k, s) = bounds
                .iter()
                .enumerate()
                .find(|&(_, &(s, e))| s <= j && j < e)
                .map(|(k, &(s, _))| (k, s))
                .expect("shard bounds cover 0..p");
            if let Ok(reg) = rep.slots[k].wait() {
                if let DesignRepr::Native(data) = &reg.repr {
                    let serial = blas::dot(&data[(j - s) * n..(j - s + 1) * n], r);
                    crate::invariants::assert_spot_identical(c[j], serial, j);
                }
            }
            j += step;
        }
    }
}

/// What the stager hands the uploader: a staged panel, or the error
/// that stopped staging. A mid-stream source failure (a corrupt
/// `.hxd` block, a vanished file) rides the channel so the uploader
/// can fail the right shard's slot with the *underlying* error — the
/// acceptance bar is a descriptive `Err` on every sweep, never a
/// panic or a hang in the pipeline.
enum Staged {
    Panel { k: usize, width: usize, data: Vec<f64> },
    Failed { k: usize, error: String },
}

/// The stager half of the upload pipeline: pulls contiguous column
/// panels out of the [`ColumnSource`] and hands them to the uploader
/// through a bounded channel (capacity 1 ⇒ double buffering: one
/// panel in flight, one being staged). With an on-disk source, shard
/// k+1 is read from the file while shard k uploads.
#[allow(clippy::too_many_arguments)]
fn upload_pipeline(
    mut source: Box<dyn ColumnSource>,
    n: usize,
    chunk: usize,
    work: Vec<(usize, usize, usize)>,
    engines: Arc<Vec<Box<dyn Backend>>>,
    slots: Arc<Vec<ShardSlot>>,
    stats: Arc<Mutex<UploadStats>>,
    hook: Option<StageHook>,
) {
    let total = work.len();
    let (tx, rx) = mpsc::sync_channel::<Staged>(1);
    let stager = {
        let stats = Arc::clone(&stats);
        std::thread::spawn(move || {
            for (k, c0, c1) in work {
                if let Some(h) = &hook {
                    h(k);
                }
                let before = source.bytes_read();
                let t = Instant::now();
                let staged = source.read_cols(c0, c1).and_then(|panel| {
                    // A source serving the wrong panel shape (or one
                    // wider than the shard chunk) would corrupt every
                    // downstream kernel — refuse it here, descriptively.
                    if panel.len() != (c1 - c0) * n || c1 - c0 > chunk {
                        Err(crate::err!(
                            "source staged {} values for columns {c0}..{c1}, expected {} \
                             (chunk {chunk})",
                            panel.len(),
                            (c1 - c0) * n
                        ))
                    } else {
                        Ok(panel)
                    }
                });
                let secs = t.elapsed().as_secs_f64();
                let panel = match staged {
                    Ok(panel) => panel,
                    Err(e) => {
                        // Stop staging: later shards are failed by the
                        // uploader's trailing loop with this cause.
                        let _ = tx.send(Staged::Failed { k, error: e.to_string() });
                        return;
                    }
                };
                #[cfg(feature = "paranoid")]
                crate::invariants::assert_staged_panel_bounded(panel.len(), n, c1 - c0, chunk);
                let bytes = 8 * panel.len() as u64;
                {
                    let mut st = lock_ignore_poison(&stats);
                    st.staged += 1;
                    st.stage_seconds += secs;
                    st.read_seconds += secs;
                    st.bytes_read += source.bytes_read() - before;
                    st.inflight_bytes += bytes;
                    st.peak_inflight_bytes = st.peak_inflight_bytes.max(st.inflight_bytes);
                    st.max_panel_bytes = st.max_panel_bytes.max(bytes);
                }
                if tx.send(Staged::Panel { k, width: c1 - c0, data: panel }).is_err() {
                    return;
                }
            }
        })
    };
    let mut source_error: Option<String> = None;
    for _ in 0..total {
        // Overlap bookkeeping: a panel already in the channel means
        // staging fully overlapped the previous upload (or the
        // caller's sweeps); otherwise the uploader stalls and the
        // stall is timed.
        let (item, was_overlapped) = match rx.try_recv() {
            Ok(v) => (v, true),
            Err(mpsc::TryRecvError::Empty) => {
                let t = Instant::now();
                match rx.recv() {
                    Ok(v) => {
                        lock_ignore_poison(&stats).stall_seconds += t.elapsed().as_secs_f64();
                        (v, false)
                    }
                    Err(_) => break,
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => break,
        };
        let (k, width, panel) = match item {
            Staged::Panel { k, width, data } => (k, width, data),
            Staged::Failed { k, error } => {
                slots[k].fail(error.clone());
                source_error = Some(error);
                break;
            }
        };
        {
            // The uploader owns the panel from here on, so it stops
            // counting against the staged-but-untaken double-buffer
            // gauge (`overlapped` only counts real panels).
            let mut st = lock_ignore_poison(&stats);
            if was_overlapped {
                st.overlapped += 1;
            }
            st.inflight_bytes = st.inflight_bytes.saturating_sub(8 * panel.len() as u64);
        }
        let t = Instant::now();
        match engines[k].register_design(&panel, n, width) {
            Ok(reg) => {
                let secs = t.elapsed().as_secs_f64();
                {
                    let mut st = lock_ignore_poison(&stats);
                    st.uploaded += 1;
                    st.upload_seconds += secs;
                }
                slots[k].fulfill(reg);
            }
            Err(e) => slots[k].fail(e.to_string()),
        }
    }
    // A dead stager (panic in a hook or in staging itself) must
    // surface as a per-shard `Err` to sweep waiters — never an
    // unwrap-abort in this thread, and never a hang: fail every slot
    // still pending (fulfilled slots ignore `fail`). A source read
    // failure names the original cause instead of a generic message.
    let leftover = match stager.join() {
        Ok(()) => match source_error {
            Some(e) => format!("an earlier shard's staging read failed: {e}"),
            None => "upload pipeline exited early".to_string(),
        },
        Err(payload) => format!("stager panicked: {}", panic_message(payload)),
    };
    for slot in slots.iter() {
        slot.fail(leftover.clone());
    }
    // Paranoid: the whole point of the fail-loop above is that no
    // waiter can be left blocking on a Pending slot once the pipeline
    // thread exits.
    #[cfg(feature = "paranoid")]
    for (i, slot) in slots.iter().enumerate() {
        assert!(
            !matches!(*lock_ignore_poison(&slot.state), SlotState::Pending),
            "shard slot {i} still pending after pipeline exit"
        );
    }
}

impl Backend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn num_ops(&self) -> usize {
        self.engines[0].num_ops()
    }

    fn threads(&self) -> usize {
        self.engines.iter().map(|e| e.threads()).sum()
    }

    fn shards(&self) -> usize {
        self.engines.len()
    }

    fn upload_stats(&self) -> Option<UploadStats> {
        let stats = lock_ignore_poison(&self.stats).clone();
        #[cfg(feature = "paranoid")]
        crate::invariants::assert_upload_stats_sane(&stats);
        Some(stats)
    }

    fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        shard_bounds(p, self.engines.len())
            .iter()
            .zip(self.engines.iter())
            .all(|(&(s, e), eng)| eng.supports_sweep(loss, n, e - s))
    }

    fn is_exact(&self) -> bool {
        self.engines.iter().all(|e| e.is_exact())
    }

    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        // One resident copy serves both the synchronous shard-0 panel
        // and the background stager (replacing the former panel-0 +
        // remaining-columns copy pair); `ResidentSource` validates the
        // shape and computes the global f64 column norms with the same
        // `blas::nrm2` the unsharded backends cache.
        self.register_source(Box::new(ResidentSource::copy_of(col_major, n, p)?))
    }

    fn register_source(&self, mut source: Box<dyn ColumnSource>) -> Result<RegisteredDesign> {
        let (n, p) = (source.n(), source.p());
        if n == 0 || p == 0 {
            return Err(crate::err!("cannot register an empty design ({n}x{p})"));
        }
        // Global column norms in f64, straight from the source's
        // manifest/precompute — no resident pass over the data (the
        // batched mask reduction needs them bitwise-exact).
        let col_norms = source.col_norms().to_vec();
        if col_norms.len() != p {
            return Err(crate::err!(
                "source reports {} column norms for p = {p}",
                col_norms.len()
            ));
        }
        let bounds = shard_bounds(p, self.engines.len());
        let chunk = div_ceil(p.max(1), self.engines.len());
        let slots: Arc<Vec<ShardSlot>> =
            Arc::new((0..bounds.len()).map(|_| ShardSlot::new()).collect());

        // Shard 0 synchronously: the caller can start sweeping it
        // while the pipeline uploads the rest. A failing first read
        // (truncated file, corrupt block 0) surfaces directly here.
        let (s0, e0) = bounds[0];
        let before = source.bytes_read();
        let t = Instant::now();
        let panel0 = source.read_cols(s0, e0)?;
        let stage0 = t.elapsed().as_secs_f64();
        if panel0.len() != (e0 - s0) * n {
            return Err(crate::err!(
                "source staged {} values for columns {s0}..{e0}, expected {}",
                panel0.len(),
                (e0 - s0) * n
            ));
        }
        #[cfg(feature = "paranoid")]
        crate::invariants::assert_staged_panel_bounded(panel0.len(), n, e0 - s0, chunk);
        let bytes0 = 8 * panel0.len() as u64;
        let t_up = Instant::now();
        let reg0 = self.engines[0].register_design(&panel0, n, e0 - s0)?;
        {
            let mut st = lock_ignore_poison(&self.stats);
            st.staged += 1;
            st.stage_seconds += stage0;
            st.read_seconds += stage0;
            st.bytes_read += source.bytes_read() - before;
            st.max_panel_bytes = st.max_panel_bytes.max(bytes0);
            st.peak_inflight_bytes = st.peak_inflight_bytes.max(bytes0);
            st.uploaded += 1;
            st.upload_seconds += t_up.elapsed().as_secs_f64();
        }
        drop(panel0);
        slots[0].fulfill(reg0);

        let uploader = if bounds.len() > 1 {
            let work: Vec<(usize, usize, usize)> = bounds
                .iter()
                .enumerate()
                .skip(1)
                .map(|(k, &(s, e))| (k, s, e))
                .collect();
            let engines = Arc::clone(&self.engines);
            let slots = Arc::clone(&slots);
            let stats = Arc::clone(&self.stats);
            let hook = self.stage_hook.clone();
            // The source moves into the pipeline thread; nothing else
            // holds design data, so the streaming path's only standing
            // allocations are the source's own buffers plus at most
            // two in-flight panels.
            Some(std::thread::spawn(move || {
                upload_pipeline(source, n, chunk, work, engines, slots, stats, hook);
            }))
        } else {
            None
        };

        Ok(RegisteredDesign {
            n,
            p,
            col_norms,
            repr: DesignRepr::Sharded(ShardedRepr {
                slots,
                uploader: Mutex::new(uploader),
            }),
        })
    }

    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let rep = Self::repr(design)?;
        let parts = self.shard_map(rep, |i, reg| self.engines[i].correlation(reg, r))?;
        let merged = parts.map(|ps| ps.into_iter().flatten().collect::<Vec<f64>>());
        // Paranoid: sampled entries of the merged vector must be
        // *bit-identical* to a serial recompute on the resident shard
        // panels — every entry is produced by the same per-column
        // `blas::dot`, so any drift means the shard offsets or the
        // concatenation order broke.
        #[cfg(feature = "paranoid")]
        if let Some(c) = merged.as_deref() {
            self.spot_check_correlation(rep, c, r, design.p);
        }
        Ok(merged)
    }

    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let rep = Self::repr(design)?;
        let parts = self.shard_map(rep, |i, reg| {
            self.engines[i].kkt_sweep(loss, reg, y, eta, lambda)
        })?;
        Ok(parts.map(|ps| {
            // Every shard computes the same n-length pseudo-residual;
            // take shard 0's and concatenate the correlation slices.
            let resid = ps[0].1.clone();
            (ps.into_iter().flat_map(|(c, _)| c).collect(), resid)
        }))
    }

    fn kkt_sweep_masked(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        // Shards split *columns*; the row mask applies uniformly to
        // every shard panel (row indices are global in each panel), so
        // the fold sweep fans out exactly like the unmasked one.
        let rep = Self::repr(design)?;
        let parts = self.shard_map(rep, |i, reg| {
            self.engines[i].kkt_sweep_masked(loss, reg, rows, y, eta, lambda)
        })?;
        Ok(parts.map(|ps| {
            // Every shard computes the same fold-length pseudo-residual
            // from the compact y/eta; take shard 0's and concatenate
            // the correlation slices in shard (= column) order.
            let resid = ps[0].1.clone();
            (ps.into_iter().flat_map(|(c, _)| c).collect(), resid)
        }))
    }

    fn kkt_sweep_batch(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        if lambdas.is_empty() {
            return Ok(None);
        }
        let rep = Self::repr(design)?;
        let parts = self.shard_map(rep, |i, reg| {
            self.engines[i].kkt_sweep_batch(loss, reg, y, eta, lambdas, l1_norm)
        })?;
        let Some(ps) = parts else {
            return Ok(None);
        };
        let resid = ps[0].resid.clone();
        let c: Vec<f64> = ps.into_iter().flat_map(|b| b.c).collect();
        // Reduction: the per-shard masks were built from shard-local
        // sup-norms and are unsound globally — rebuild every mask from
        // the merged correlation vector and the global ‖Xᵀr‖∞, exactly
        // as the unsharded native kernel does (bit-identical).
        let xt_inf = blas::amax(&c);
        let keep = lambdas
            .iter()
            .map(|&l| {
                let gap = loss.duality_gap(y, eta, &resid, xt_inf, l, l1_norm);
                crate::screening::lookahead_keep(&c, &design.col_norms, xt_inf, gap, l, 0.0)
            })
            .collect();
        Ok(Some(KktBatch { c, resid, keep }))
    }

    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        if xe_t.len() != e * n || xd_t.len() != d * n || w.is_some_and(|w| w.len() != n) {
            return Err(crate::err!(
                "gram_block shape mismatch: xe {}, xd {}, w {} for (e={e}, d={d}, n={n})",
                xe_t.len(),
                xd_t.len(),
                w.map_or(n, <[f64]>::len)
            ));
        }
        if e * d == 0 {
            return Ok(Some(Vec::new()));
        }
        let k = self.engines.len().min(e);
        if k == 1 {
            return self.engines[0].gram_block(xe_t, w, xd_t, e, d, n);
        }
        // Fan the panel's rows out across the shard engines; each row
        // is computed by the same scalar kernel, so the row-major
        // concatenation is bit-identical to the unsharded panel.
        let rows_per = div_ceil(e, k);
        let results: Vec<Result<Option<Vec<f64>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..k)
                .map(|i| {
                    let r0 = (i * rows_per).min(e);
                    let r1 = ((i + 1) * rows_per).min(e);
                    let eng = &self.engines[i];
                    s.spawn(move || {
                        if r0 == r1 {
                            Ok(Some(Vec::new()))
                        } else {
                            eng.gram_block(&xe_t[r0 * n..r1 * n], w, xd_t, r1 - r0, d, n)
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("panel shard worker panicked"))
                .collect()
        });
        let mut out = Vec::with_capacity(e * d);
        for r in results {
            match r? {
                Some(mut block) => out.append(&mut block),
                None => return Ok(None),
            }
        }
        Ok(Some(out))
    }
}

/// A host-resident [`Design`] view over a registered design's shard
/// panels: per-column kernels run on the engines' own slices through
/// the exact blas calls `DenseMatrix` uses, so a path fit through
/// this view is **bit-identical** to a fit over the original dense
/// matrix — without any single n×p allocation (the design lives in k
/// per-shard panels). This is what lets `hx fit --design file.hxd`
/// run the whole solver out-of-core-registered yet bitwise-equal.
///
/// Construction blocks until every shard upload lands and surfaces
/// any upload failure as an `Err`; the view borrows the panels, so it
/// costs no copies.
pub struct ShardedDesignView<'a> {
    n: usize,
    p: usize,
    /// Uniform shard width `ceil(p/k)`: column `j` lives in panel
    /// `j / chunk` at local column `j % chunk`.
    chunk: usize,
    panels: Vec<&'a [f64]>,
}

impl<'a> ShardedDesignView<'a> {
    pub fn new(design: &'a RegisteredDesign) -> Result<Self> {
        match &design.repr {
            DesignRepr::Sharded(rep) => {
                let bounds = shard_bounds(design.p, rep.slots.len());
                let chunk = div_ceil(design.p.max(1), rep.slots.len());
                let mut panels = Vec::with_capacity(rep.slots.len());
                for (slot, &(s, e)) in rep.slots.iter().zip(&bounds) {
                    let reg = slot.wait()?;
                    match &reg.repr {
                        DesignRepr::Native(data) => {
                            if data.len() != (e - s) * design.n {
                                return Err(crate::err!(
                                    "shard panel holds {} values for columns {s}..{e}, \
                                     expected {}",
                                    data.len(),
                                    (e - s) * design.n
                                ));
                            }
                            panels.push(data.as_slice());
                        }
                        _ => {
                            return Err(crate::err!(
                                "shard panels are not host-resident; a design view needs \
                                 native shard engines"
                            ))
                        }
                    }
                }
                Ok(Self { n: design.n, p: design.p, chunk, panels })
            }
            DesignRepr::Native(data) => Ok(Self {
                n: design.n,
                p: design.p,
                chunk: design.p.max(1),
                panels: vec![data.as_slice()],
            }),
            #[cfg(feature = "pjrt")]
            DesignRepr::Pjrt(_) => Err(crate::err!(
                "device-resident designs have no host-side view"
            )),
        }
    }

    #[inline]
    fn col(&self, j: usize) -> &[f64] {
        let k = j / self.chunk;
        let local = j - k * self.chunk;
        &self.panels[k][local * self.n..(local + 1) * self.n]
    }
}

impl Design for ShardedDesignView<'_> {
    fn nrows(&self) -> usize {
        self.n
    }

    fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        blas::dot(self.col(j), v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]) {
        blas::axpy(alpha, self.col(j), v);
    }

    #[inline]
    fn col_sq_norm(&self, j: usize) -> f64 {
        blas::sq_norm(self.col(j))
    }

    fn gram(&self, i: usize, j: usize) -> f64 {
        blas::dot(self.col(i), self.col(j))
    }

    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64 {
        match w {
            None => self.gram(i, j),
            Some(w) => blas::dot_w(self.col(i), self.col(j), w),
        }
    }

    fn density(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DesignMatrix, SyntheticSpec};

    fn dense_problem(n: usize, p: usize, seed: u64) -> (crate::linalg::DenseMatrix, Vec<f64>) {
        let data = SyntheticSpec::new(n, p, 5).rho(0.3).seed(seed).generate();
        let dense = match data.design {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!(),
        };
        (dense, data.response)
    }

    #[test]
    fn bounds_cover_ragged_and_degenerate() {
        assert_eq!(shard_bounds(10, 4), vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        assert_eq!(shard_bounds(8, 1), vec![(0, 8)]);
        assert_eq!(shard_bounds(3, 5), vec![(0, 1), (1, 2), (2, 3), (3, 3), (3, 3)]);
        for (p, k) in [(10, 4), (8, 1), (3, 5), (100, 7)] {
            let b = shard_bounds(p, k);
            assert_eq!(b.len(), k);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[k - 1].1, p);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "shards must be contiguous");
            }
        }
    }

    #[test]
    fn sharded_correlation_is_bit_identical() {
        let (n, p) = (30, 53); // ragged for every shard count below
        let (dense, y) = dense_problem(n, p, 7);
        let reference = NativeBackend::default();
        let reg_ref = reference.register_design(dense.data(), n, p).unwrap();
        let c_ref = reference.correlation(&reg_ref, &y).unwrap().unwrap();
        for shards in [1, 2, 4, 7] {
            let b = ShardedBackend::native(shards, 1);
            let reg = b.register_design(dense.data(), n, p).unwrap();
            let c = b.correlation(&reg, &y).unwrap().unwrap();
            assert_eq!(c, c_ref, "{shards} shards");
            assert_eq!(reg.col_norms, reg_ref.col_norms, "{shards} shards norms");
        }
    }

    #[test]
    fn sharded_batch_masks_use_the_global_sup_norm() {
        // The dominant column sits in the *last* shard, so a
        // shard-local reduction would compute the wrong dual scale for
        // every other shard. The merged masks must match the unsharded
        // kernel exactly.
        let (n, p) = (25, 40);
        let (dense, y) = dense_problem(n, p, 11);
        let eta = vec![0.0; n];
        let lambdas = [0.8, 0.6, 0.4];
        let reference = NativeBackend::default();
        let reg_ref = reference.register_design(dense.data(), n, p).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let want = reference
                .kkt_sweep_batch(loss, &reg_ref, &y, &eta, &lambdas, 0.0)
                .unwrap()
                .unwrap();
            for shards in [2, 3, 4] {
                let b = ShardedBackend::native(shards, 1);
                let reg = b.register_design(dense.data(), n, p).unwrap();
                let got = b
                    .kkt_sweep_batch(loss, &reg, &y, &eta, &lambdas, 0.0)
                    .unwrap()
                    .unwrap();
                assert_eq!(got.c, want.c, "{loss:?} {shards} shards c");
                assert_eq!(got.resid, want.resid, "{loss:?} {shards} shards resid");
                assert_eq!(got.keep, want.keep, "{loss:?} {shards} shards masks");
            }
        }
        // Poisson and empty λ batches stay unavailable, not errors.
        let b = ShardedBackend::native(2, 1);
        let reg = b.register_design(dense.data(), n, p).unwrap();
        assert!(b
            .kkt_sweep_batch(Loss::Poisson, &reg, &y, &eta, &lambdas, 0.0)
            .unwrap()
            .is_none());
        assert!(b
            .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &[], 0.0)
            .unwrap()
            .is_none());
    }

    #[test]
    fn sharded_masked_sweep_is_bit_identical() {
        // The fold mask applies row-wise while shards split columns:
        // every shard count must reproduce the unsharded masked sweep
        // bit-for-bit (ragged p exercises uneven shard widths).
        let (n, p) = (30, 53);
        let (dense, y) = dense_problem(n, p, 13);
        let rows: Vec<usize> = (0..n).filter(|i| i % 5 != 3).collect();
        let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
        let ef = vec![0.0; rows.len()];
        let reference = NativeBackend::default();
        let reg_ref = reference.register_design(dense.data(), n, p).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let (c_ref, r_ref) = reference
                .kkt_sweep_masked(loss, &reg_ref, &rows, &yf, &ef, 0.5)
                .unwrap()
                .unwrap();
            for shards in [1, 2, 4, 7] {
                let b = ShardedBackend::native(shards, 1);
                let reg = b.register_design(dense.data(), n, p).unwrap();
                let (c, r) = b
                    .kkt_sweep_masked(loss, &reg, &rows, &yf, &ef, 0.5)
                    .unwrap()
                    .unwrap();
                assert_eq!(c, c_ref, "{loss:?} {shards} shards c");
                assert_eq!(r, r_ref, "{loss:?} {shards} shards resid");
            }
        }
        let b = ShardedBackend::native(2, 1);
        let reg = b.register_design(dense.data(), n, p).unwrap();
        assert!(b
            .kkt_sweep_masked(Loss::Poisson, &reg, &rows, &yf, &ef, 0.5)
            .unwrap()
            .is_none());
    }

    #[test]
    fn upload_pipeline_counts_every_panel() {
        let (n, p) = (20, 37);
        let (dense, y) = dense_problem(n, p, 3);
        let b = ShardedBackend::native(4, 1);
        let reg = b.register_design(dense.data(), n, p).unwrap();
        // A sweep blocks until every shard is resident, so the stats
        // are complete afterwards.
        let _ = b.correlation(&reg, &y).unwrap().unwrap();
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, 4);
        assert_eq!(u.uploaded, 4);
        assert!(u.overlapped <= 3, "only pipelined shards can overlap");
        // Second registration accumulates.
        let reg2 = b.register_design(dense.data(), n, p).unwrap();
        let _ = b.correlation(&reg2, &y).unwrap().unwrap();
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, 8);
        assert_eq!(u.uploaded, 8);
    }

    #[test]
    fn stager_panic_surfaces_as_error_not_hang() {
        let (n, p) = (15, 32);
        let (dense, y) = dense_problem(n, p, 5);
        // The hook panics before staging pipelined panel 2: shards 0
        // (synchronous) and 1 become resident, shards 2 and 3 must
        // fail with the panic message — and a sweep must return that
        // error instead of blocking forever on a pending slot.
        let b = ShardedBackend::native(4, 1).with_stage_hook(Arc::new(|k| {
            if k == 2 {
                panic!("injected stager panic");
            }
        }));
        let reg = b.register_design(dense.data(), n, p).unwrap();
        let err = b.correlation(&reg, &y).unwrap_err().to_string();
        assert!(err.contains("stager panicked"), "{err}");
        assert!(err.contains("injected stager panic"), "{err}");
        // The resident shards stayed balanced: shard 0 and panel 1
        // staged and uploaded, panels 2 and 3 never staged.
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, 2);
        assert_eq!(u.uploaded, 2);
    }

    #[test]
    fn slow_stager_stalls_are_counted_and_balanced() {
        let (n, p) = (20, 44);
        let (dense, y) = dense_problem(n, p, 9);
        // 4 shards with a 25 ms injected stage delay per pipelined
        // panel: the uploader must record stall time (staging is the
        // bottleneck by construction) while the counters stay balanced
        // once the design is fully resident.
        let b = ShardedBackend::native(4, 1).with_stage_hook(delay_hook(25));
        let reg = b.register_design(dense.data(), n, p).unwrap();
        let _ = b.correlation(&reg, &y).unwrap().unwrap();
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, 4);
        assert_eq!(u.uploaded, 4);
        assert!(u.overlapped <= u.uploaded);
        assert!(
            u.stall_seconds > 0.0,
            "a 25 ms stage delay must stall the uploader"
        );

        // 1 shard: no pipeline, so the hook never runs (it would
        // panic) and nothing can overlap or stall.
        let b1 = ShardedBackend::native(1, 1)
            .with_stage_hook(Arc::new(|_| panic!("hook must not run without a pipeline")));
        let reg1 = b1.register_design(dense.data(), n, p).unwrap();
        let _ = b1.correlation(&reg1, &y).unwrap().unwrap();
        let u1 = b1.upload_stats().unwrap();
        assert_eq!(u1.staged, 1);
        assert_eq!(u1.uploaded, 1);
        assert_eq!(u1.overlapped, 0);
        assert_eq!(u1.stall_seconds, 0.0);
    }

    #[test]
    fn foreign_or_malformed_designs_are_rejected() {
        let (n, p) = (10, 6);
        let (dense, y) = dense_problem(n, p, 1);
        let b = ShardedBackend::native(2, 1);
        assert!(b.register_design(&dense.data()[1..], n, p).is_err());
        // A native-registered design handed to the sharded backend is
        // an error, not a silent wrong answer.
        let native = NativeBackend::default();
        let foreign = native.register_design(dense.data(), n, p).unwrap();
        assert!(b.correlation(&foreign, &y).is_err());
    }

    #[test]
    fn reports_shards_threads_and_exactness() {
        let b = ShardedBackend::native(3, 2);
        assert_eq!(b.name(), "sharded");
        assert_eq!(b.shards(), 3);
        assert_eq!(b.threads(), 6);
        assert!(b.is_exact());
        assert!(b.supports_sweep(Loss::Gaussian, 50, 10));
        assert!(!b.supports_sweep(Loss::Poisson, 50, 10));
    }

    #[test]
    fn register_source_streams_bit_identical_to_resident() {
        let (n, p) = (22, 37);
        let (dense, y) = dense_problem(n, p, 13);
        let b = ShardedBackend::native(4, 1);
        let reg_a = b.register_design(dense.data(), n, p).unwrap();
        let src = ResidentSource::copy_of(dense.data(), n, p).unwrap();
        let reg_b = b.register_source(Box::new(src)).unwrap();
        assert_eq!(reg_a.col_norms, reg_b.col_norms);
        let ca = b.correlation(&reg_a, &y).unwrap().unwrap();
        let cb = b.correlation(&reg_b, &y).unwrap().unwrap();
        assert_eq!(ca, cb);
    }

    #[test]
    fn pipeline_counters_bound_the_double_buffer() {
        let (n, p, shards) = (20, 36, 4); // chunk = 9 columns
        let (dense, y) = dense_problem(n, p, 17);
        let b = ShardedBackend::native(shards, 1);
        let reg = b.register_design(dense.data(), n, p).unwrap();
        let _ = b.correlation(&reg, &y).unwrap().unwrap();
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, shards);
        assert_eq!(u.uploaded, shards);
        // Every column crossed the source seam exactly once.
        assert_eq!(u.bytes_read, (8 * n * p) as u64);
        assert!(u.read_seconds >= 0.0 && u.read_seconds <= u.stage_seconds);
        // Quiescent pipeline: nothing staged-but-untaken, and the
        // high-water mark respected the double-buffer depth.
        assert_eq!(u.inflight_bytes, 0);
        let panel_cap = (8 * n * div_ceil(p, shards)) as u64;
        assert_eq!(u.max_panel_bytes, panel_cap);
        assert!(
            u.max_panel_bytes < (8 * n * p) as u64,
            "no full-design panel may exist on the streaming path"
        );
        assert!(
            u.peak_inflight_bytes <= 2 * u.max_panel_bytes,
            "peak staged bytes {} exceeded two panels ({})",
            u.peak_inflight_bytes,
            2 * u.max_panel_bytes
        );
    }

    /// A source whose reads start failing after `ok_reads` calls —
    /// the deterministic stand-in for a disk that dies mid-stream.
    struct FlakySource {
        inner: ResidentSource,
        ok_reads: usize,
        reads: usize,
    }

    impl ColumnSource for FlakySource {
        fn n(&self) -> usize {
            self.inner.n()
        }

        fn p(&self) -> usize {
            self.inner.p()
        }

        fn col_norms(&self) -> &[f64] {
            self.inner.col_norms()
        }

        fn read_cols(&mut self, c0: usize, c1: usize) -> Result<Vec<f64>> {
            self.reads += 1;
            if self.reads > self.ok_reads {
                return Err(crate::err!("disk went away reading columns {c0}..{c1}"));
            }
            self.inner.read_cols(c0, c1)
        }

        fn bytes_read(&self) -> u64 {
            self.inner.bytes_read()
        }

        fn source_name(&self) -> &'static str {
            "flaky"
        }
    }

    #[test]
    fn mid_stream_read_failure_is_an_error_not_a_hang() {
        let (n, p) = (15, 32);
        let (dense, y) = dense_problem(n, p, 19);
        // 4 shards; reads 1 (shard 0) and 2 (panel 1) succeed, the
        // read for panel 2 fails: registration itself succeeds, every
        // sweep must surface the read error, and the counters must
        // stay balanced with no panel left in flight.
        let flaky = FlakySource {
            inner: ResidentSource::copy_of(dense.data(), n, p).unwrap(),
            ok_reads: 2,
            reads: 0,
        };
        let b = ShardedBackend::native(4, 1);
        let reg = b.register_source(Box::new(flaky)).unwrap();
        let err = b.correlation(&reg, &y).unwrap_err().to_string();
        assert!(err.contains("disk went away"), "{err}");
        let u = b.upload_stats().unwrap();
        assert_eq!(u.staged, 2);
        assert_eq!(u.uploaded, 2);
        assert_eq!(u.inflight_bytes, 0);

        // A first read that fails surfaces synchronously from
        // registration (the "source open / first read" surface).
        let dead = FlakySource {
            inner: ResidentSource::copy_of(dense.data(), n, p).unwrap(),
            ok_reads: 0,
            reads: 0,
        };
        let err = b.register_source(Box::new(dead)).unwrap_err().to_string();
        assert!(err.contains("disk went away"), "{err}");
    }

    #[test]
    fn design_view_matches_dense_kernels_bitwise() {
        let (n, p) = (18, 23);
        let (dense, y) = dense_problem(n, p, 21);
        let w: Vec<f64> = (0..n).map(|i| 0.5 + 0.01 * i as f64).collect();
        // 30 shards > p exercises empty trailing shards.
        for shards in [1, 2, 5, 30] {
            let b = ShardedBackend::native(shards, 1);
            let reg = b.register_design(dense.data(), n, p).unwrap();
            let view = ShardedDesignView::new(&reg).unwrap();
            assert_eq!((view.nrows(), view.ncols()), (n, p));
            assert_eq!(view.density(), 1.0);
            for j in 0..p {
                assert_eq!(
                    view.col_dot(j, &y).to_bits(),
                    dense.col_dot(j, &y).to_bits(),
                    "{shards} shards col {j}"
                );
                assert_eq!(
                    view.col_sq_norm(j).to_bits(),
                    dense.col_sq_norm(j).to_bits()
                );
                let mut a = vec![0.25; n];
                let mut c = vec![0.25; n];
                view.col_axpy(j, 1.25, &mut a);
                dense.col_axpy(j, 1.25, &mut c);
                assert_eq!(a, c);
            }
            assert_eq!(view.gram(3, 11).to_bits(), dense.gram(3, 11).to_bits());
            assert_eq!(
                view.gram_weighted(2, 9, Some(&w)).to_bits(),
                dense.gram_weighted(2, 9, Some(&w)).to_bits()
            );
            assert_eq!(
                view.gram_weighted(2, 9, None).to_bits(),
                dense.gram(2, 9).to_bits()
            );
        }

        // A native-registered design exposes the same view.
        let native = NativeBackend::default();
        let reg = native.register_design(dense.data(), n, p).unwrap();
        let view = ShardedDesignView::new(&reg).unwrap();
        assert_eq!(view.col_dot(7, &y).to_bits(), dense.col_dot(7, &y).to_bits());
    }

    #[test]
    fn design_view_surfaces_failed_uploads() {
        let (n, p) = (15, 32);
        let (dense, _) = dense_problem(n, p, 23);
        let b = ShardedBackend::native(4, 1).with_stage_hook(Arc::new(|k| {
            if k == 2 {
                panic!("injected stager panic");
            }
        }));
        let reg = b.register_design(dense.data(), n, p).unwrap();
        let err = ShardedDesignView::new(&reg).unwrap_err().to_string();
        assert!(err.contains("stager panicked"), "{err}");
    }
}
