//! BLAS-level micro-kernels.
//!
//! These are the innermost loops of the whole system: the correlation
//! sweep (Xᵀr) and coordinate-descent updates spend essentially all of
//! their time in `dot` and `axpy`. They are written with 4-way manual
//! unrolling and independent accumulators so LLVM auto-vectorizes them
//! to AVX on this target; we verified the vectorization in the perf pass
//! (see EXPERIMENTS.md §Perf).

/// xᵀy with 8 independent accumulators.
///
/// Perf note (EXPERIMENTS.md §Perf L3): the 8-lane accumulator array
/// auto-vectorizes to two AVX FMA chains, ~8% faster on the full
/// correlation sweep than the earlier 4-accumulator form (interleaved
/// best-of-15 A/B); a 16-lane variant measured < 5% further and was
/// rejected per the one-change protocol.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f64; 8];
    for i in 0..chunks {
        let b = i * 8;
        for (k, a) in acc.iter_mut().enumerate() {
            // SAFETY: b + k <= (chunks-1)*8 + 7 < chunks*8 <= n = x.len(),
            // and y.len() == x.len() (debug_assert above; all callers pass
            // equal-length slices).
            unsafe {
                *a += x.get_unchecked(b + k) * y.get_unchecked(b + k);
            }
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += x[i] * y[i];
    }
    s
}

/// y ← y + alpha·x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    let n = x.len();
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        // SAFETY: b + 3 <= (chunks-1)*4 + 3 < chunks*4 <= n = x.len() ==
        // y.len() (debug_assert above).
        unsafe {
            *y.get_unchecked_mut(b) += alpha * x.get_unchecked(b);
            *y.get_unchecked_mut(b + 1) += alpha * x.get_unchecked(b + 1);
            *y.get_unchecked_mut(b + 2) += alpha * x.get_unchecked(b + 2);
            *y.get_unchecked_mut(b + 3) += alpha * x.get_unchecked(b + 3);
        }
    }
    for i in chunks * 4..n {
        y[i] += alpha * x[i];
    }
}

/// Fused dot of one column with two vectors at once: (xᵀa, xᵀb).
/// Saves a full pass over x in the weighted-gram and dual computations.
#[inline]
pub fn dot2(x: &[f64], a: &[f64], b: &[f64]) -> (f64, f64) {
    debug_assert_eq!(x.len(), a.len());
    debug_assert_eq!(x.len(), b.len());
    let n = x.len();
    let (mut s0, mut s1) = (0.0, 0.0);
    for i in 0..n {
        // SAFETY: i < n = x.len(), and a.len() == b.len() == x.len()
        // (debug_asserts above).
        unsafe {
            let xi = *x.get_unchecked(i);
            s0 += xi * a.get_unchecked(i);
            s1 += xi * b.get_unchecked(i);
        }
    }
    (s0, s1)
}

/// Weighted dot Σ wᵢ xᵢ yᵢ.
#[inline]
pub fn dot_w(x: &[f64], y: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), w.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        // SAFETY: i < x.len(), and y.len() == w.len() == x.len()
        // (debug_asserts above).
        unsafe {
            s += w.get_unchecked(i) * x.get_unchecked(i) * y.get_unchecked(i);
        }
    }
    s
}

/// ‖x‖₂².
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    dot(x, x)
}

/// ‖x‖₂.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    sq_norm(x).sqrt()
}

/// ‖x‖₁.
#[inline]
pub fn asum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// max |xᵢ|.
#[inline]
pub fn amax(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// y ← x.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// x ← alpha·x.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Soft-thresholding operator S(z, t) = sign(z)·max(|z|−t, 0): the
/// elementary step of ℓ₁ coordinate descent.
#[inline(always)]
pub fn soft_threshold(z: f64, t: f64) -> f64 {
    if z > t {
        z - t
    } else if z < -t {
        z + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(x: &[f64], y: &[f64]) -> f64 {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }

    #[test]
    fn dot_matches_naive_various_lengths() {
        for n in [0, 1, 2, 3, 4, 5, 7, 8, 17, 64, 100, 257] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
            let got = dot(&x, &y);
            let want = naive_dot(&x, &y);
            assert!((got - want).abs() < 1e-10 * (1.0 + want.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0, 1, 3, 4, 9, 33, 128] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 + 0.5).collect();
            let mut y: Vec<f64> = (0..n).map(|i| (i as f64).sqrt()).collect();
            let mut y2 = y.clone();
            axpy(1.75, &x, &mut y);
            for i in 0..n {
                y2[i] += 1.75 * x[i];
            }
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn axpy_zero_alpha_is_noop() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![4.0, 5.0, 6.0];
        axpy(0.0, &x, &mut y);
        assert_eq!(y, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn dot2_consistent_with_dot() {
        let x: Vec<f64> = (0..37).map(|i| (i as f64 * 0.3).sin()).collect();
        let a: Vec<f64> = (0..37).map(|i| (i as f64 * 0.7).cos()).collect();
        let b: Vec<f64> = (0..37).map(|i| i as f64 * 0.01).collect();
        let (da, db) = dot2(&x, &a, &b);
        assert!((da - dot(&x, &a)).abs() < 1e-12);
        assert!((db - dot(&x, &b)).abs() < 1e-12);
    }

    #[test]
    fn weighted_dot() {
        let x = vec![1.0, 2.0, 3.0];
        let y = vec![2.0, 0.5, 1.0];
        let w = vec![0.25, 0.25, 0.5];
        assert!((dot_w(&x, &y, &w) - (0.5 + 0.25 + 1.5)).abs() < 1e-14);
    }

    #[test]
    fn norms_and_amax() {
        let x = vec![3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-14);
        assert!((sq_norm(&x) - 25.0).abs() < 1e-14);
        assert!((asum(&x) - 7.0).abs() < 1e-14);
        assert!((amax(&x) - 4.0).abs() < 1e-14);
        assert_eq!(amax(&[]), 0.0);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn scal_and_copy() {
        let mut x = vec![1.0, -2.0, 4.0];
        scal(0.5, &mut x);
        assert_eq!(x, vec![0.5, -1.0, 2.0]);
        let mut y = vec![0.0; 3];
        copy(&x, &mut y);
        assert_eq!(x, y);
    }
}
