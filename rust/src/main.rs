//! `hx` — the hessian-screening coordinator CLI.
//!
//! Subcommands:
//!   fit            fit one regularization path (synthetic, catalog, or
//!                  out-of-core `.hxd` data via `--design`)
//!   pack           write a dataset/CSV to a checksummed columnar `.hxd` file
//!   exp <id>       regenerate a paper table/figure (fig1…fig12, tab1, tab3, all)
//!   cv             k-fold cross-validated λ selection
//!   homotopy       adaptive-grid (approximate homotopy) lasso path
//!   runtime-check  load AOT artifacts via PJRT and cross-check vs native
//!   list           datasets, methods, experiments
//!
//! Run `hx <cmd> --help` conventions: every option is `--key value`.

use hessian_screening::cli::Args;
use hessian_screening::cv::{cross_validate_with_engine, thread_plan, CvFit, CvSettings, CvStats};
use hessian_screening::coordinator::Coordinator;
use hessian_screening::data::{dataset_by_name, dataset_catalog, SyntheticSpec};
use hessian_screening::experiments::{self, ExpConfig};
use hessian_screening::linalg::Design;
use hessian_screening::loss::Loss;
use hessian_screening::metrics::{fmt_secs, Summary, Table};
use hessian_screening::path::{
    fit_approximate_homotopy, HomotopySettings, PathFit, PathFitter, PathSettings, StepStats,
};
use hessian_screening::runtime::{EngineSweep, RuntimeEngine, ShardedDesignView};
use hessian_screening::screening::ScreeningKind;
use hessian_screening::storage::{pack_dense, read_csv, ColumnSource, HxdSource, DEFAULT_BLOCK_COLS};

const USAGE: &str = "\
hx — Hessian Screening Rule (Larsson & Wallin, NeurIPS 2022) reproduction

USAGE:
  hx fit [--dataset NAME | --n N --p P --s S] [--rho R] [--snr S]
         [--loss gaussian|logistic|poisson] [--method hessian|strong|working|
          celer|blitz|gap_safe|edpp|sasvi|none] [--path-length M] [--eps E]
         [--gamma G] [--seed K] [--engine] [--threads T] [--shards K]
         [--lookahead B] [--profile]
  hx fit --design FILE.hxd [--shards K] [--threads T] [--method M]
         [--path-length M] [--eps E] [--gamma G] [--lookahead B] [--profile]
         (loss and response come from the packed file; shard panels
          stream from disk — the design is never resident in one piece)
  hx pack --out FILE.hxd [--dataset NAME | --n N --p P --s S [--rho R]
         [--snr S] [--loss L] [--seed K] | --csv FILE [--csv-response]]
         [--block-cols B]
  hx exp <fig1|fig2|fig3|tab1|fig4|fig5|fig6|tab3|fig8|fig9|fig10|fig11|fig12|all>
         [--reps R] [--full] [--out DIR] [--threads T] [--seed K]
         [--datasets a,b,c]   (tab1 only)
  hx cv  [--dataset NAME | --n N --p P --s S] [--folds K] [--method M]
         [--loss L] [--path-length M] [--seed K] [--folds-seed K]
         [--threads T] [--engine-threads E] [--shards K] [--profile]
         (fold fits run through zero-copy row-masked views of the one
          design; T splits as cv_workers × engine_threads ≤ T)
  hx cv  --design FILE.hxd [--folds K] [--method M] [--path-length M]
         [--shards K] [--threads T] [--engine-threads E] [--folds-seed K]
         [--profile]
  hx homotopy [--n N --p P --s S] [--rho R] [--min-ratio X]
  hx runtime-check [--artifacts DIR]   (native backend when artifacts or
                                        the `pjrt` feature are absent)
  hx list
";

fn parse_loss(s: &str) -> Result<Loss, String> {
    match s.to_ascii_lowercase().as_str() {
        "gaussian" | "lasso" | "ls" | "least-squares" => Ok(Loss::Gaussian),
        "logistic" | "binomial" => Ok(Loss::Logistic),
        "poisson" => Ok(Loss::Poisson),
        other => Err(format!("unknown loss '{other}'")),
    }
}

fn main() {
    let args = Args::from_env();
    let code = match args.pos(0) {
        Some("fit") => cmd_fit(&args),
        Some("pack") => cmd_pack(&args),
        Some("exp") => cmd_exp(&args),
        Some("cv") => cmd_cv(&args),
        Some("homotopy") => cmd_homotopy(&args),
        Some("runtime-check") => cmd_runtime_check(&args),
        Some("list") => cmd_list(),
        _ => {
            eprint!("{USAGE}");
            Err("missing or unknown subcommand".to_string())
        }
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn path_settings_from(args: &Args) -> Result<PathSettings, String> {
    let mut s = PathSettings::default();
    if let Some(m) = args.get_usize("path-length")? {
        s.path_length = m;
    }
    if let Some(e) = args.get_f64("eps")? {
        s.cd.eps = e;
    }
    if let Some(g) = args.get_f64("gamma")? {
        s.gamma = g;
    }
    if let Some(r) = args.get_f64("min-ratio")? {
        s.lambda_min_ratio = Some(r);
    }
    if args.flag("no-warm-starts") {
        s.hessian_warm_starts = false;
    }
    if args.flag("no-gap-safe") {
        s.use_gap_safe_aug = false;
    }
    if args.flag("no-sweep") {
        s.hessian_sweep_updates = false;
    }
    if let Some(seed) = args.get_usize("seed")? {
        s.seed = seed as u64;
    }
    Ok(s)
}

/// Shard-pipeline observability line, shared by the resident and
/// out-of-core fit paths.
fn print_upload_stats(engine: Option<&RuntimeEngine>) {
    if let Some(u) = engine.and_then(RuntimeEngine::upload_stats) {
        let mib = u.bytes_read as f64 / (1024.0 * 1024.0);
        let rate = if u.read_seconds > 0.0 { mib / u.read_seconds } else { 0.0 };
        eprintln!(
            "(shard uploads: {} staged, {} uploaded, {} overlapped; \
             stage {}s upload {}s stall {}s; read {mib:.1} MiB in {}s \
             ({rate:.0} MiB/s), peak in-flight {:.1} MiB)",
            u.staged,
            u.uploaded,
            u.overlapped,
            fmt_secs(u.stage_seconds),
            fmt_secs(u.upload_seconds),
            fmt_secs(u.stall_seconds),
            fmt_secs(u.read_seconds),
            u.peak_inflight_bytes as f64 / (1024.0 * 1024.0)
        );
    }
}

/// Per-step path table + totals, shared by the resident and
/// out-of-core fit paths.
fn print_fit_report(
    name: &str,
    n: usize,
    p: usize,
    loss: Loss,
    kind: ScreeningKind,
    fit: &PathFit,
    secs: f64,
) {
    println!("dataset={name} n={n} p={p} loss={loss:?} method={kind}");
    let mut table = Table::new(&["step", "lambda", "active", "screened", "passes", "dev.ratio"]);
    let m = fit.lambdas.len();
    for k in (0..m).step_by((m / 15).max(1)) {
        let s = &fit.steps[k];
        table.row(vec![
            format!("{k}"),
            format!("{:.4}", fit.lambdas[k]),
            format!("{}", s.active),
            format!("{}", s.screened),
            format!("{}", s.passes),
            format!("{:.4}", s.dev_ratio),
        ]);
    }
    println!("{}", table.render());
    println!(
        "steps={} total_passes={} violations={} time={}s",
        m,
        fit.total_passes(),
        fit.total_violations(),
        fmt_secs(secs)
    );
}

/// `--profile`: per-step kernel-time breakdown in milliseconds. The
/// sweep column is the engine-sweep share of kkt, panel the Gram-panel
/// share of hessian, and alloc the bytes of workspace growth that step
/// (0 in the steady state — the allocation-free-hot-path observable).
fn print_profile(fit: &PathFit) {
    let mut table = Table::new(&[
        "step", "lambda", "cd.ms", "kkt.ms", "sweep.ms", "hess.ms", "panel.ms", "screen.ms",
        "alloc.B",
    ]);
    for (k, s) in fit.steps.iter().enumerate() {
        table.row(vec![
            format!("{k}"),
            format!("{:.4}", fit.lambdas.get(k).copied().unwrap_or(f64::NAN)),
            format!("{:.3}", s.t_cd * 1e3),
            format!("{:.3}", s.t_kkt * 1e3),
            format!("{:.3}", s.t_sweep * 1e3),
            format!("{:.3}", s.t_hessian * 1e3),
            format!("{:.3}", s.t_panel * 1e3),
            format!("{:.3}", s.t_screen * 1e3),
            format!("{}", s.alloc_bytes),
        ]);
    }
    println!("{}", table.render());
    let sum = |f: fn(&StepStats) -> f64| -> f64 { fit.steps.iter().map(f).sum() };
    let alloc: usize = fit.steps.iter().map(|s| s.alloc_bytes).sum();
    let steady = fit.steps.iter().skip(1).filter(|s| s.alloc_bytes == 0).count();
    println!(
        "profile: cd={}s kkt={}s (sweep={}s) hessian={}s (panel={}s) screen={}s \
         workspace_growth={alloc}B steady_steps={steady}/{}",
        fmt_secs(sum(|s| s.t_cd)),
        fmt_secs(sum(|s| s.t_kkt)),
        fmt_secs(sum(|s| s.t_sweep)),
        fmt_secs(sum(|s| s.t_hessian)),
        fmt_secs(sum(|s| s.t_panel)),
        fmt_secs(sum(|s| s.t_screen)),
        fit.steps.len().saturating_sub(1)
    );
}

fn cmd_fit(args: &Args) -> Result<(), String> {
    if args.get("design").is_some() {
        return cmd_fit_hxd(args);
    }
    let loss = parse_loss(args.get("loss").unwrap_or("gaussian"))?;
    let kind = ScreeningKind::parse(args.get("method").unwrap_or("hessian"))
        .ok_or("unknown --method")?;
    let data = if let Some(name) = args.get("dataset") {
        dataset_by_name(name)
            .ok_or_else(|| format!("unknown dataset '{name}' (see `hx list`)"))?
            .generate(args.get_usize("seed")?.unwrap_or(0) as u64)
    } else {
        let n = args.get_usize("n")?.unwrap_or(200);
        let p = args.get_usize("p")?.unwrap_or(2_000);
        let s = args.get_usize("s")?.unwrap_or(10);
        let rho = args.get_f64("rho")?.unwrap_or(0.3);
        let snr = args.get_f64("snr")?.unwrap_or(2.0);
        experiments::simulate(n, p, s, rho, snr, loss, args.get_usize("seed")?.unwrap_or(0) as u64)
    };
    let loss = data.loss; // catalog datasets carry their own loss
    let settings = path_settings_from(args)?;
    let fitter = PathFitter::new(loss, kind).with_settings(settings);

    // Optional sweep engine: PJRT artifacts when built with the `pjrt`
    // feature and compiled, the pure-Rust NativeBackend otherwise.
    // `--threads T` enables the engine with T-way chunked
    // column-parallel native kernels (0 = all cores); `--shards K`
    // splits the design into K column shards with pipelined uploads
    // (each shard gets `--threads` workers, default 1); `--lookahead B`
    // sets the batched look-ahead width (default 4, 0 disables).
    let threads = args.get_usize("threads")?;
    let shards = args.get_usize("shards")?;
    let engine = if args.flag("engine") || threads.is_some() || shards.is_some() {
        let native = || match shards {
            Some(k) => RuntimeEngine::native_sharded(k.max(1), threads.unwrap_or(1)),
            None => RuntimeEngine::native_threaded(threads.unwrap_or(1)),
        };
        Some(if args.flag("engine") {
            match RuntimeEngine::load_default() {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("(artifacts unavailable: {err}; using the native backend)");
                    native()
                }
            }
        } else {
            native()
        })
    } else {
        None
    };
    let t = std::time::Instant::now();
    let fit = match (&engine, &data.design) {
        (Some(eng), hessian_screening::data::DesignMatrix::Dense(m)) => {
            match EngineSweep::new(eng, m, loss).map_err(|e| e.to_string())? {
                Some(mut sweep) => {
                    if let Some(b) = args.get_usize("lookahead")? {
                        sweep = sweep.with_lookahead(b);
                    }
                    eprintln!(
                        "(full KKT sweeps via the {} backend, {} shard(s), {} thread(s), look-ahead {})",
                        eng.backend_name(),
                        eng.shards(),
                        eng.threads(),
                        sweep.lookahead
                    );
                    fitter.fit_with_engine(&data.design, &data.response, Some(&sweep))
                }
                None => {
                    eprintln!("(no sweep kernel for this shape; native sweeps)");
                    fitter.fit(&data.design, &data.response)
                }
            }
        }
        _ => fitter.fit(&data.design, &data.response),
    };
    let secs = t.elapsed().as_secs_f64();
    print_upload_stats(engine.as_ref());
    print_fit_report(&data.name, data.n(), data.p(), loss, kind, &fit, secs);
    if args.flag("profile") {
        print_profile(&fit);
    }
    Ok(())
}

/// `hx fit --design FILE.hxd`: fit a path with the design streamed
/// shard-by-shard from a packed `.hxd` file. Loss and response come
/// from the file; coefficients are bit-identical to a resident fit of
/// the same data (same blas kernels, same reduction order).
fn cmd_fit_hxd(args: &Args) -> Result<(), String> {
    let path = std::path::PathBuf::from(args.get("design").expect("routed on --design"));
    let mut source = HxdSource::open(&path).map_err(|e| e.to_string())?;
    let loss = source.loss();
    let kind = ScreeningKind::parse(args.get("method").unwrap_or("hessian"))
        .ok_or("unknown --method")?;
    let y = source.take_response().ok_or_else(|| {
        format!(
            "{} was packed without a response; re-pack with one \
             (a dataset/synthetic spec, or `--csv … --csv-response`)",
            path.display()
        )
    })?;
    let (n, p) = (source.n(), source.p());
    let name = path.display().to_string();
    let fitter = PathFitter::new(loss, kind).with_settings(path_settings_from(args)?);

    let shards = args.get_usize("shards")?.unwrap_or(1).max(1);
    let threads = args.get_usize("threads")?.unwrap_or(1);
    let engine = RuntimeEngine::native_sharded(shards, threads);

    // Decide the sweep question *before* handing the source over: the
    // source is consumed by registration, and both branches stream it
    // through the sharded pipeline (never a resident n×p buffer here).
    let t = std::time::Instant::now();
    let fit = if engine.supports_sweep(loss, n, p) {
        let mut sweep = EngineSweep::from_source(&engine, Box::new(source), loss)
            .map_err(|e| e.to_string())?
            .expect("supports_sweep checked above");
        if let Some(b) = args.get_usize("lookahead")? {
            sweep = sweep.with_lookahead(b);
        }
        eprintln!(
            "(streaming {name} through the {} backend, {} shard(s), {} thread(s), look-ahead {})",
            engine.backend_name(),
            engine.shards(),
            engine.threads(),
            sweep.lookahead
        );
        let view = ShardedDesignView::new(&sweep.design).map_err(|e| e.to_string())?;
        fitter.fit_with_engine(&view, &y, Some(&sweep))
    } else {
        let reg = engine
            .register_source(Box::new(source))
            .map_err(|e| e.to_string())?;
        eprintln!("(no sweep kernel for this shape; native sweeps over the streamed design)");
        let view = ShardedDesignView::new(&reg).map_err(|e| e.to_string())?;
        fitter.fit(&view, &y)
    };
    let secs = t.elapsed().as_secs_f64();
    print_upload_stats(Some(&engine));
    print_fit_report(&name, n, p, loss, kind, &fit, secs);
    if args.flag("profile") {
        print_profile(&fit);
    }
    Ok(())
}

/// `hx pack`: write a dataset (catalog, synthetic, or CSV) to a
/// checksummed columnar `.hxd` file for out-of-core fitting.
fn cmd_pack(args: &Args) -> Result<(), String> {
    let out = std::path::PathBuf::from(
        args.get("out").ok_or("hx pack needs --out FILE.hxd (see `hx` usage)")?,
    );
    let block_cols = args.get_usize("block-cols")?.unwrap_or(DEFAULT_BLOCK_COLS);
    let (dense, response, loss, what) = if let Some(csv) = args.get("csv") {
        let csv_path = std::path::PathBuf::from(csv);
        let loss = parse_loss(args.get("loss").unwrap_or("gaussian"))?;
        let (m, y) = read_csv(&csv_path, args.flag("csv-response")).map_err(|e| e.to_string())?;
        (m, y, loss, csv.to_string())
    } else {
        let loss = parse_loss(args.get("loss").unwrap_or("gaussian"))?;
        let data = if let Some(dname) = args.get("dataset") {
            dataset_by_name(dname)
                .ok_or_else(|| format!("unknown dataset '{dname}' (see `hx list`)"))?
                .generate(args.get_usize("seed")?.unwrap_or(0) as u64)
        } else {
            let n = args.get_usize("n")?.unwrap_or(200);
            let p = args.get_usize("p")?.unwrap_or(2_000);
            let s = args.get_usize("s")?.unwrap_or(10);
            let rho = args.get_f64("rho")?.unwrap_or(0.3);
            let snr = args.get_f64("snr")?.unwrap_or(2.0);
            experiments::simulate(
                n,
                p,
                s,
                rho,
                snr,
                loss,
                args.get_usize("seed")?.unwrap_or(0) as u64,
            )
        };
        let name = data.name.clone();
        let loss = data.loss;
        match data.design {
            hessian_screening::data::DesignMatrix::Dense(m) => {
                (m, Some(data.response), loss, name)
            }
            hessian_screening::data::DesignMatrix::Sparse(_) => {
                return Err(format!(
                    "dataset '{name}' is sparse; .hxd stores dense f64 columns — \
                     pick a dense dataset or a synthetic spec"
                ));
            }
        }
    };
    let summary = pack_dense(&out, &dense, block_cols, loss, response.as_deref())
        .map_err(|e| e.to_string())?;
    println!(
        "packed {what} -> {}: n={} p={} loss={loss:?} block_cols={} blocks={} \
         response={} size={:.1} MiB",
        out.display(),
        summary.n,
        summary.p,
        summary.block_cols,
        summary.blocks,
        if response.is_some() { "yes" } else { "no" },
        summary.bytes as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<(), String> {
    let name = args.pos(1).ok_or("usage: hx exp <id> (see `hx list`)")?;
    let mut cfg = ExpConfig {
        reps: args.get_usize("reps")?.unwrap_or(3),
        full: args.flag("full"),
        out_dir: args.get("out").map(std::path::PathBuf::from),
        threads: args
            .get_usize("threads")?
            .unwrap_or_else(|| Coordinator::auto().threads),
        seed: args.get_usize("seed")?.unwrap_or(0x9E15) as u64,
    };
    if cfg.out_dir.is_none() {
        cfg.out_dir = Some(std::path::PathBuf::from("results"));
    }
    if name == "tab1" {
        if let Some(list) = args.get_list("datasets") {
            return experiments::real_data::run_subset(&cfg, Some(&list));
        }
    }
    experiments::run_experiment(name, &cfg)
}

/// CV thread budget: `--threads T` is the *total* budget, split by
/// [`thread_plan`] into fold workers × per-fold engine threads
/// (`--engine-threads` pins the engine share, clamped to the budget).
fn cv_threads_from(args: &Args, n_folds: usize) -> Result<(usize, usize), String> {
    let total = args
        .get_usize("threads")?
        .unwrap_or_else(|| Coordinator::auto().threads);
    let eng = args.get_usize("engine-threads")?.unwrap_or(0);
    Ok(thread_plan(total, n_folds, eng))
}

/// CV curve table + selection summary, shared by the resident and
/// out-of-core CV paths. The table samples ~20 grid rows but always
/// includes the `<- min` and `<- 1se` marker rows (the stride used to
/// skip them entirely on longer paths).
fn print_cv_report(cv: &CvFit, n_folds: usize, secs: f64) {
    let mut table = Table::new(&["lambda", "cv deviance", "se", ""]);
    let m = cv.lambdas.len();
    let mut rows: Vec<usize> = (0..m).step_by((m / 20).max(1)).collect();
    for k in [cv.idx_min, cv.idx_1se] {
        if k < m && !rows.contains(&k) {
            rows.push(k);
        }
    }
    rows.sort_unstable();
    rows.dedup();
    for k in rows {
        let marker = if k == cv.idx_min {
            "<- min"
        } else if k == cv.idx_1se {
            "<- 1se"
        } else {
            ""
        };
        table.row(vec![
            format!("{:.4}", cv.lambdas[k]),
            format!("{:.4}", cv.cv_mean[k]),
            format!("{:.4}", cv.cv_se[k]),
            marker.into(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "lambda_min={:.4} ({} predictors), lambda_1se={:.4} ({} predictors), {} folds in {}s",
        cv.lambda_min(),
        cv.selected_coefs(false).len(),
        cv.lambda_1se(),
        cv.selected_coefs(true).len(),
        n_folds,
        fmt_secs(secs)
    );
}

/// `hx cv --profile`: per-fold wall/kernel breakdown plus the thread /
/// routing configuration. `alloc.B` is workspace arena growth over the
/// fold's whole path — folds after a worker's first report ≈ 0 (the
/// warm-fold-path observable).
fn print_cv_profile(stats: &CvStats) {
    let mut table = Table::new(&[
        "fold", "wall.ms", "cd.ms", "kkt.ms", "sweep.ms", "hess.ms", "screen.ms", "alloc.B",
        "screened", "steps", "passes",
    ]);
    for f in &stats.folds {
        table.row(vec![
            format!("{}", f.fold),
            format!("{:.3}", f.wall_seconds * 1e3),
            format!("{:.3}", f.t_cd * 1e3),
            format!("{:.3}", f.t_kkt * 1e3),
            format!("{:.3}", f.t_sweep * 1e3),
            format!("{:.3}", f.t_hessian * 1e3),
            format!("{:.3}", f.t_screen * 1e3),
            format!("{}", f.alloc_bytes),
            format!("{:.1}", f.mean_screened),
            format!("{}", f.steps),
            format!("{}", f.passes),
        ]);
    }
    println!("{}", table.render());
    let wall = Summary::over(&stats.folds, |f| f.wall_seconds);
    let sweeps: usize = stats.folds.iter().map(|f| f.full_sweeps).sum();
    let alloc: usize = stats.folds.iter().map(|f| f.alloc_bytes).sum();
    println!(
        "cv profile: {} fold worker(s) x {} engine thread(s), {} shard(s), {}; \
         fold wall {}s +/- {}s; {sweeps} full sweeps; workspace growth {alloc}B",
        stats.cv_threads,
        stats.engine_threads,
        stats.engine_shards,
        if stats.routed { "engine-routed" } else { "host-path" },
        fmt_secs(wall.mean),
        fmt_secs(wall.ci_half),
    );
}

fn cmd_cv(args: &Args) -> Result<(), String> {
    if args.get("design").is_some() {
        return cmd_cv_hxd(args);
    }
    let loss = parse_loss(args.get("loss").unwrap_or("gaussian"))?;
    let kind = ScreeningKind::parse(args.get("method").unwrap_or("hessian"))
        .ok_or("unknown --method")?;
    let data = if let Some(name) = args.get("dataset") {
        dataset_by_name(name)
            .ok_or_else(|| format!("unknown dataset '{name}'"))?
            .generate(args.get_usize("seed")?.unwrap_or(0) as u64)
    } else {
        let n = args.get_usize("n")?.unwrap_or(200);
        let p = args.get_usize("p")?.unwrap_or(1_000);
        let s = args.get_usize("s")?.unwrap_or(10);
        experiments::simulate(
            n,
            p,
            s,
            args.get_f64("rho")?.unwrap_or(0.3),
            args.get_f64("snr")?.unwrap_or(3.0),
            loss,
            args.get_usize("seed")?.unwrap_or(0) as u64,
        )
    };
    let loss = data.loss;
    let n_folds = args.get_usize("folds")?.unwrap_or(10);
    let (cv_threads, engine_threads) = cv_threads_from(args, n_folds)?;
    let settings = CvSettings {
        n_folds,
        seed: args.get_usize("folds-seed")?.unwrap_or(0) as u64,
        path: path_settings_from(args)?,
        threads: cv_threads,
        engine_threads,
    };
    // Dense designs route fold sweeps through the native engine
    // (sharded when asked); sparse designs fit on the host path.
    let shards = args.get_usize("shards")?;
    let engine = match shards {
        Some(k) => RuntimeEngine::native_sharded(k.max(1), engine_threads),
        None => RuntimeEngine::native_threaded(engine_threads),
    };
    let sweep = match &data.design {
        hessian_screening::data::DesignMatrix::Dense(m) => {
            EngineSweep::new(&engine, m, loss).map_err(|e| e.to_string())?
        }
        _ => None,
    };
    if let Some(es) = &sweep {
        eprintln!(
            "(fold sweeps via the {} backend: {} fold worker(s) x {} engine thread(s), {} shard(s))",
            engine.backend_name(),
            cv_threads,
            es.engine.threads(),
            es.engine.shards(),
        );
    }
    let t = std::time::Instant::now();
    let cv = cross_validate_with_engine(
        &data.design,
        &data.response,
        loss,
        kind,
        &settings,
        sweep.as_ref(),
    );
    let secs = t.elapsed().as_secs_f64();
    print_cv_report(&cv, settings.n_folds, secs);
    if args.flag("profile") {
        print_cv_profile(&cv.stats);
    }
    Ok(())
}

/// `hx cv --design FILE.hxd`: cross-validate with the design streamed
/// shard-by-shard from a packed `.hxd` file. The design registers with
/// the engine once; every fold is a row-masked view over the same
/// registration (no per-fold copies, no per-fold re-registration).
fn cmd_cv_hxd(args: &Args) -> Result<(), String> {
    let path = std::path::PathBuf::from(args.get("design").expect("routed on --design"));
    let mut source = HxdSource::open(&path).map_err(|e| e.to_string())?;
    let loss = source.loss();
    let kind = ScreeningKind::parse(args.get("method").unwrap_or("hessian"))
        .ok_or("unknown --method")?;
    let y = source.take_response().ok_or_else(|| {
        format!(
            "{} was packed without a response; re-pack with one \
             (a dataset/synthetic spec, or `--csv … --csv-response`)",
            path.display()
        )
    })?;
    let (n, p) = (source.n(), source.p());
    let n_folds = args.get_usize("folds")?.unwrap_or(10);
    let (cv_threads, engine_threads) = cv_threads_from(args, n_folds)?;
    let settings = CvSettings {
        n_folds,
        seed: args.get_usize("folds-seed")?.unwrap_or(0) as u64,
        path: path_settings_from(args)?,
        threads: cv_threads,
        engine_threads,
    };
    let shards = args.get_usize("shards")?.unwrap_or(1).max(1);
    let engine = RuntimeEngine::native_sharded(shards, engine_threads);

    // Decide the sweep question *before* handing the source over (the
    // source is consumed by registration); either way the design
    // streams through the sharded pipeline exactly once.
    let t = std::time::Instant::now();
    let cv = if engine.supports_sweep(loss, n, p) {
        let sweep = EngineSweep::from_source(&engine, Box::new(source), loss)
            .map_err(|e| e.to_string())?
            .expect("supports_sweep checked above");
        eprintln!(
            "(streaming {} through the {} backend: {} fold worker(s) x {} engine thread(s), {} shard(s))",
            path.display(),
            engine.backend_name(),
            cv_threads,
            engine.threads(),
            engine.shards(),
        );
        let view = ShardedDesignView::new(&sweep.design).map_err(|e| e.to_string())?;
        cross_validate_with_engine(&view, &y, loss, kind, &settings, Some(&sweep))
    } else {
        let reg = engine
            .register_source(Box::new(source))
            .map_err(|e| e.to_string())?;
        eprintln!("(no sweep kernel for this shape; host-path folds over the streamed design)");
        let view = ShardedDesignView::new(&reg).map_err(|e| e.to_string())?;
        cross_validate_with_engine(&view, &y, loss, kind, &settings, None)
    };
    let secs = t.elapsed().as_secs_f64();
    print_upload_stats(Some(&engine));
    print_cv_report(&cv, settings.n_folds, secs);
    if args.flag("profile") {
        print_cv_profile(&cv.stats);
    }
    Ok(())
}

fn cmd_homotopy(args: &Args) -> Result<(), String> {
    let n = args.get_usize("n")?.unwrap_or(200);
    let p = args.get_usize("p")?.unwrap_or(1_000);
    let s = args.get_usize("s")?.unwrap_or(10);
    let rho = args.get_f64("rho")?.unwrap_or(0.3);
    let data = SyntheticSpec::new(n, p, s)
        .rho(rho)
        .snr(2.0)
        .seed(args.get_usize("seed")?.unwrap_or(0) as u64)
        .generate();
    let mut settings = HomotopySettings::default();
    if let Some(r) = args.get_f64("min-ratio")? {
        settings.lambda_min_ratio = r;
    }
    let fit = fit_approximate_homotopy(&data.design, &data.response, &settings);
    let mut table = Table::new(&["step", "lambda", "active", "passes"]);
    for (k, s) in fit.steps.iter().enumerate() {
        table.row(vec![
            format!("{k}"),
            format!("{:.5}", s.lambda),
            format!("{}", s.active),
            format!("{}", s.passes),
        ]);
    }
    println!("{}", table.render());
    println!(
        "adaptive grid: {} breakpoint-driven steps (vs {} fixed), time={}s",
        fit.lambdas.len(),
        PathSettings::default().path_length,
        fmt_secs(fit.total_time)
    );
    Ok(())
}

fn cmd_runtime_check(args: &Args) -> Result<(), String> {
    let explicit_dir = args.get("artifacts");
    let dir = std::path::PathBuf::from(explicit_dir.unwrap_or("artifacts"));
    let engine = match RuntimeEngine::load_dir(&dir) {
        Ok(e) => {
            println!(
                "loaded {} compiled artifacts from {} ({} backend)",
                e.num_ops(),
                dir.display(),
                e.backend_name()
            );
            e
        }
        Err(err) if explicit_dir.is_some() => {
            // The user named a directory: a load failure is a real
            // failure, not an occasion to silently pass on the
            // native backend.
            return Err(format!("loading artifacts from {}: {err}", dir.display()));
        }
        Err(err) => {
            println!("artifacts unavailable ({err}); checking the native backend");
            RuntimeEngine::native()
        }
    };

    // Cross-check the 200x2000 sweep against the native path.
    let (n, p) = (200usize, 2_000usize);
    let data = SyntheticSpec::new(n, p, 10).rho(0.3).seed(1).generate();
    let dense = match &data.design {
        hessian_screening::data::DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };
    let reg = engine
        .register_design(dense.data(), n, p)
        .map_err(|e| e.to_string())?;
    let r: Vec<f64> = data.response.clone();
    let (c_pjrt, secs) = hessian_screening::metrics::timed(|| {
        engine.correlation(&reg, &r).map_err(|e| e.to_string())
    });
    let c_pjrt = c_pjrt?.ok_or("no xt_r kernel for 200x2000")?;
    let mut c_native = vec![0.0; p];
    let (_, native_secs) = hessian_screening::metrics::timed(|| {
        for (j, c) in c_native.iter_mut().enumerate() {
            *c = dense.col_dot(j, &r);
        }
    });
    let max_diff = c_pjrt
        .iter()
        .zip(&c_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let scale = c_native.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    println!(
        "xt_r 200x2000: {}={}s native={}s max|Δ|={max_diff:.3e} (scale {scale:.3e})",
        engine.backend_name(),
        fmt_secs(secs),
        fmt_secs(native_secs)
    );
    if max_diff > 1e-3 * scale.max(1.0) {
        return Err(format!(
            "{}/native mismatch: {max_diff}",
            engine.backend_name()
        ));
    }
    println!(
        "runtime-check OK ({} backend agrees with the native f64 reference)",
        engine.backend_name()
    );
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    println!("datasets (simulated analogues of the paper's Table 1):");
    let mut t = Table::new(&["name", "n", "p", "density", "loss", "scaling"]);
    for d in dataset_catalog() {
        t.row(vec![
            d.name.into(),
            format!("{}", d.n),
            format!("{}", d.p),
            format!("{:.2}", d.density.unwrap_or(1.0)),
            format!("{:?}", d.loss),
            d.scale_note.into(),
        ]);
    }
    println!("{}", t.render());
    println!("methods: {}", ScreeningKind::all().map(|k| k.name()).join(", "));
    println!("experiments: {}", experiments::EXPERIMENTS.join(", "));
    Ok(())
}
