"""Tests for the perf-trajectory gate (python/ci/bench_compare.py).

Pure stdlib — exercised through the CLI surface (the exact invocation
`make perf-gate` uses), no jax required.
"""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[1] / "ci" / "bench_compare.py"


def record(
    name="kkt_sweep",
    backend="native",
    threads=1,
    shards=1,
    batch=1,
    design="resident",
    wall=1e-3,
):
    return {
        "name": name,
        "n": 200,
        "p": 4000,
        "backend": backend,
        "threads": threads,
        "shards": shards,
        "batch": batch,
        "design": design,
        "wall_seconds": wall,
        "ci_half": wall / 20,
    }


def run_gate(tmp_path, fresh, baseline, *extra):
    fresh_p = tmp_path / "fresh.json"
    base_p = tmp_path / "baseline.json"
    fresh_p.write_text(json.dumps(fresh))
    base_p.write_text(json.dumps(baseline))
    return subprocess.run(
        [sys.executable, str(SCRIPT), str(fresh_p), str(base_p), *extra],
        capture_output=True,
        text=True,
    )


def test_within_threshold_passes(tmp_path):
    base = [record(wall=1e-3), record(name="correlation", wall=2e-3)]
    fresh = [record(wall=1.1e-3), record(name="correlation", wall=1.9e-3)]
    r = run_gate(tmp_path, fresh, base)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "perf-gate: 2 record(s) compared" in r.stdout
    assert "WARN" not in r.stdout


def test_warn_band_does_not_fail(tmp_path):
    r = run_gate(tmp_path, [record(wall=1.3e-3)], [record(wall=1e-3)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "WARN" in r.stdout


def test_fail_level_regression_exits_nonzero(tmp_path):
    r = run_gate(tmp_path, [record(wall=2e-3)], [record(wall=1e-3)])
    assert r.returncode == 1
    assert "FAIL" in r.stdout
    assert "refresh" in r.stdout  # points at the baseline ritual


def test_noise_floor_never_gates(tmp_path):
    # 2 µs baseline: a 10x "regression" is runner jitter, not signal.
    r = run_gate(tmp_path, [record(wall=2e-5)], [record(wall=2e-6)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "below noise floor" in r.stdout


def test_missing_and_new_keys_are_reported_not_gated(tmp_path):
    base = [record(), record(name="gone", wall=1e-3)]
    fresh = [record(), record(name="brand_new", backend="sharded", shards=2, wall=9.0)]
    r = run_gate(tmp_path, fresh, base)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "missing in fresh run" in r.stdout
    assert "new since baseline" in r.stdout


def test_legacy_baseline_without_shards_field_defaults_to_one(tmp_path):
    legacy = record(wall=1e-3)
    del legacy["shards"]  # baselines predating the sharded backend
    r = run_gate(tmp_path, [record(wall=1.05e-3)], [legacy])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "perf-gate: 1 record(s) compared" in r.stdout


def test_legacy_baseline_without_design_field_defaults_to_resident(tmp_path):
    # Mirrors the shards migration: records predating out-of-core
    # storage carry no design field and must key as "resident".
    legacy = record(wall=1e-3)
    del legacy["design"]
    r = run_gate(tmp_path, [record(wall=1.05e-3)], [legacy])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "perf-gate: 1 record(s) compared" in r.stdout


def test_design_field_separates_resident_and_hxd_records(tmp_path):
    # Same kernel name and shard count, different design substrate:
    # these are different keys and must never gate against each other.
    base = [
        record(name="register_hxd", backend="sharded", shards=2, wall=4e-3),
        record(name="register_hxd", backend="sharded", shards=2, design="hxd", wall=5e-3),
    ]
    fresh = [
        record(name="register_hxd", backend="sharded", shards=2, wall=4e-3),
        # 10x slower resident-keyed record would fail if keys collided.
        record(name="register_hxd", backend="sharded", shards=2, design="hxd", wall=5.1e-3),
    ]
    r = run_gate(tmp_path, fresh, base)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "perf-gate: 2 record(s) compared" in r.stdout
    assert "d=hxd" in r.stdout and "d=resident" in r.stdout


def test_unreadable_input_is_a_usage_error(tmp_path):
    base_p = tmp_path / "baseline.json"
    base_p.write_text(json.dumps([record()]))
    r = subprocess.run(
        [sys.executable, str(SCRIPT), str(tmp_path / "nope.json"), str(base_p)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0
    assert "cannot read" in r.stderr


def test_malformed_json_is_a_usage_error(tmp_path):
    fresh_p = tmp_path / "fresh.json"
    base_p = tmp_path / "baseline.json"
    fresh_p.write_text("{not json")
    base_p.write_text(json.dumps([record()]))
    r = subprocess.run(
        [sys.executable, str(SCRIPT), str(fresh_p), str(base_p)],
        capture_output=True,
        text=True,
    )
    assert r.returncode != 0
    assert "not valid JSON" in r.stderr
