//! Bench: Figure 1 / Figure 7 / Table 3 — screening effectiveness.
//! `cargo bench --bench fig1_screening` (quick preset; pass --full via
//! `hx exp fig1 --full` for paper-scale).

use hessian_screening::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        reps: 2,
        ..Default::default()
    };
    experiments::run_experiment("fig1", &cfg).expect("fig1");
    experiments::run_experiment("tab3", &cfg).expect("tab3");
}
