//! Figure 3: time to fit a full path on simulated data — the paper's
//! headline benchmark. Low-dimensional (n=10 000, p=100, s=5, SNR 1)
//! and high-dimensional (n=400, p=40 000, s=20, SNR 2) scenarios,
//! ρ ∈ {0, 0.4, 0.8}, ℓ₁-least-squares and logistic, with the Hessian,
//! working+, Blitz and Celer methods. Reported time is relative to the
//! minimal mean time in each (scenario, loss, ρ) group, as in the
//! paper's plot.

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

struct Cell {
    scenario: &'static str,
    loss: Loss,
    rho: f64,
    kind: ScreeningKind,
    rep: u64,
}

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let scenarios: Vec<(&'static str, (usize, usize, usize), f64)> = vec![
        ("low-dim", cfg.low_dim(), 1.0),
        ("high-dim", cfg.high_dim(), 2.0),
    ];
    let mut cells = Vec::new();
    for (name, _, _) in &scenarios {
        for loss in [Loss::Gaussian, Loss::Logistic] {
            for &rho in &[0.0, 0.4, 0.8] {
                for kind in main_methods() {
                    for rep in 0..cfg.reps as u64 {
                        cells.push(Cell {
                            scenario: name,
                            loss,
                            rho,
                            kind,
                            rep,
                        });
                    }
                }
            }
        }
    }
    let dims: std::collections::HashMap<&str, ((usize, usize, usize), f64)> = scenarios
        .iter()
        .map(|(n, d, s)| (*n, (*d, *s)))
        .collect();
    let results = cfg.coordinator().run_with_progress("fig3", cells, |i, c| {
        let ((n, p, s), snr) = dims[c.scenario];
        let data = simulate(n, p, s, c.rho, snr, c.loss, cfg.cell_seed(i as u64 / 4, c.rep));
        let (_, secs) = fit_timed(&data, c.kind, &paper_settings());
        ((c.scenario, c.loss, c.rho, c.kind), secs)
    });

    let mut table = Table::new(&[
        "Scenario", "Loss", "rho", "Method", "Time (s)", "CI lo", "CI hi", "Relative",
    ]);
    for (name, _, _) in &scenarios {
        for loss in [Loss::Gaussian, Loss::Logistic] {
            for &rho in &[0.0, 0.4, 0.8] {
                let group: Vec<(ScreeningKind, Summary)> = main_methods()
                    .into_iter()
                    .map(|kind| {
                        let times: Vec<f64> = results
                            .iter()
                            .filter(|(c, _)| {
                                c.0 == *name && c.1 == loss && c.2 == rho && c.3 == kind
                            })
                            .map(|(_, t)| *t)
                            .collect();
                        (kind, Summary::of(&times))
                    })
                    .collect();
                let min_mean = group
                    .iter()
                    .map(|(_, s)| s.mean)
                    .fold(f64::INFINITY, f64::min);
                for (kind, s) in group {
                    table.row(vec![
                        name.to_string(),
                        format!("{loss:?}"),
                        format!("{rho}"),
                        kind.name().into(),
                        format!("{}", sig_figs(s.mean, 3)),
                        format!("{}", sig_figs(s.lo(), 3)),
                        format!("{}", sig_figs(s.hi(), 3)),
                        format!("{}", sig_figs(s.mean / min_mean, 3)),
                    ]);
                }
            }
        }
    }
    println!("\nFigure 3 — time to fit a full path (simulated, relative to group min)");
    println!("{}", table.render());
    write_csv(cfg, "fig3_simulated", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hessian_not_slower_in_miniature_high_dim() {
        // Shape check on a miniature of the high-dim cell: the Hessian
        // method should beat (or tie) working+ on identical input.
        let data = simulate(80, 2_000, 8, 0.4, 2.0, Loss::Gaussian, 12);
        let settings = paper_settings();
        let mut t_h = 0.0;
        let mut t_w = 0.0;
        // median of 3 to de-noise CI timers
        for _ in 0..3 {
            t_h += fit_timed(&data, ScreeningKind::Hessian, &settings).1;
            t_w += fit_timed(&data, ScreeningKind::Working, &settings).1;
        }
        assert!(
            t_h <= t_w * 1.5,
            "hessian {t_h:.3}s vs working {t_w:.3}s — outside paper band"
        );
    }
}
