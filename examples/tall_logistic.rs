//! Tall-data logistic regression (n ≫ p): the ijcnn1/YearPredictionMSD
//! regime where the paper's *warm starts* — not the screening — provide
//! the dominant speedup (Discussion, §5: "the much-improved warm
//! starts ... enable our method to dominate in the n ≫ p setting").
//!
//!     cargo run --release --example tall_logistic

use hessian_screening::metrics::{fmt_secs, Table};
use hessian_screening::prelude::*;

fn main() {
    // ijcnn1-like: 35 000 x 22 dense logistic problem.
    let data = SyntheticSpec::new(35_000, 22, 12)
        .rho(0.2)
        .snr(1.0)
        .loss(Loss::Logistic)
        .signal_scale(0.5)
        .seed(17)
        .generate();
    println!("workload: n={} p={} (ijcnn1 analogue, logistic)\n", data.n(), data.p());

    let mut table = Table::new(&["method", "warm starts", "time (s)", "passes", "steps"]);
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working, ScreeningKind::Celer] {
        let fit = PathFitter::new(Loss::Logistic, kind).fit(&data.design, &data.response);
        table.row(vec![
            kind.name().into(),
            if kind == ScreeningKind::Hessian { "eq. (7)" } else { "standard" }.into(),
            fmt_secs(fit.total_time),
            format!("{}", fit.total_passes()),
            format!("{}", fit.lambdas.len()),
        ]);
    }

    // Ablate the warm start inside the Hessian method to isolate its
    // contribution (the Fig. 2 effect on real-ish data).
    let mut settings = hessian_screening::path::PathSettings::default();
    settings.hessian_warm_starts = false;
    let no_ws = PathFitter::new(Loss::Logistic, ScreeningKind::Hessian)
        .with_settings(settings)
        .fit(&data.design, &data.response);
    table.row(vec![
        "hessian".into(),
        "disabled".into(),
        fmt_secs(no_ws.total_time),
        format!("{}", no_ws.total_passes()),
        format!("{}", no_ws.lambdas.len()),
    ]);
    println!("{}", table.render());

    let with_ws = PathFitter::new(Loss::Logistic, ScreeningKind::Hessian)
        .fit(&data.design, &data.response);
    println!(
        "warm-start effect: {} passes with eq. (7) vs {} without",
        with_ws.total_passes(),
        no_ws.total_passes()
    );
    assert!(with_ws.total_passes() <= no_ws.total_passes());
}
