//! λ-grid construction (paper §4): a log-spaced path of `m` values from
//! λ_max down to ξ·λ_max with ξ = 10⁻² when p > n and 10⁻⁴ otherwise —
//! the glmnet defaults the paper adopts.

/// Paper/glmnet default for ξ = λ_min/λ_max.
pub fn default_lambda_min_ratio(n: usize, p: usize) -> f64 {
    if p > n {
        1e-2
    } else {
        1e-4
    }
}

/// Log-spaced grid of `m` values from `lambda_max` to
/// `ratio·lambda_max` inclusive, strictly decreasing.
pub fn lambda_grid(lambda_max: f64, ratio: f64, m: usize) -> Vec<f64> {
    assert!(lambda_max > 0.0, "lambda_max must be positive");
    // Both bounds exclusive: ratio = 0 would put λ = 0 at the end of
    // the grid, and every downstream `…/λ` (Gap-Safe radius, dual
    // scaling) would blow up to ±inf/NaN.
    assert!(ratio > 0.0 && ratio < 1.0, "ratio must be in (0,1)");
    assert!(m >= 1);
    if m == 1 {
        return vec![lambda_max];
    }
    let log_max = lambda_max.ln();
    let log_min = (lambda_max * ratio).ln();
    (0..m)
        .map(|k| {
            let t = k as f64 / (m - 1) as f64;
            (log_max + t * (log_min - log_max)).exp()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        assert_eq!(default_lambda_min_ratio(100, 1000), 1e-2);
        assert_eq!(default_lambda_min_ratio(1000, 100), 1e-4);
        assert_eq!(default_lambda_min_ratio(100, 100), 1e-4); // p > n strict
    }

    #[test]
    fn grid_endpoints_and_monotonicity() {
        let g = lambda_grid(2.0, 1e-2, 100);
        assert_eq!(g.len(), 100);
        assert!((g[0] - 2.0).abs() < 1e-12);
        assert!((g[99] - 0.02).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn grid_is_log_spaced() {
        let g = lambda_grid(1.0, 1e-4, 5);
        let ratios: Vec<f64> = g.windows(2).map(|w| w[1] / w[0]).collect();
        for r in &ratios {
            assert!((r - ratios[0]).abs() < 1e-12);
        }
    }

    #[test]
    fn single_point_grid() {
        assert_eq!(lambda_grid(3.0, 0.5, 1), vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "ratio must be in (0,1)")]
    fn zero_ratio_is_rejected() {
        // Regression: ratio = 0 used to be accepted, producing a grid
        // ending in λ = 0 and ±inf/NaN in every downstream `…/λ`.
        let _ = lambda_grid(1.0, 0.0, 10);
    }

    #[test]
    #[should_panic(expected = "ratio must be in (0,1)")]
    fn unit_ratio_is_rejected() {
        let _ = lambda_grid(1.0, 1.0, 10);
    }
}
