//! Subproblem solver: cyclic coordinate descent with shuffling (§4),
//! duality-gap convergence `G(β, θ) ≤ ε·ζ`, and the Blitz-style line
//! search for non-quadratic losses (§4, footnote 4).
//!
//! The solver always works on a *working set* `W` of predictor indices
//! — the screening rules and the path driver (paper Alg. 2) decide what
//! goes into `W`; this module solves
//!
//! ```text
//! minimize over {β : supp(β) ⊆ W} of  f(β; X) + λ‖β‖₁ (+ φ‖β‖²/2)
//! ```
//!
//! to duality gap ε·ζ and reports how many coordinate-descent passes it
//! used (the quantity plotted in the paper's Figure 2).
//!
//! For the Gaussian loss, coordinate descent runs directly on the
//! quadratic objective with an exactly-maintained residual. For general
//! losses (§3.3.3) we use proximal-Newton steps: coordinate descent on
//! the local quadratic model followed by a backtracking line search on
//! the true objective (the "line search algorithm used in Blitz").

#![forbid(unsafe_code)]

use crate::linalg::blas::{self, soft_threshold};
use crate::linalg::Design;
use crate::loss::Loss;
use crate::rng::Xoshiro256pp;

/// Solver configuration (defaults follow the paper's §4).
#[derive(Clone, Debug)]
pub struct CdSettings {
    /// Duality-gap tolerance multiplier: converged when G ≤ eps·ζ.
    pub eps: f64,
    /// Hard cap on coordinate-descent passes per subproblem.
    pub max_passes: usize,
    /// CD epochs per prox-Newton quadratic model (GLM losses).
    pub inner_epochs: usize,
    /// Backtracking line search on prox-Newton steps (Blitz §4).
    pub line_search: bool,
    /// Elastic-net quadratic penalty φ (0 = pure lasso).
    pub phi: f64,
    /// Shuffle coordinate order each pass (paper: "with shuffling").
    pub shuffle: bool,
}

impl Default for CdSettings {
    fn default() -> Self {
        Self {
            eps: 1e-4,
            max_passes: 10_000,
            inner_epochs: 1,
            line_search: true,
            phi: 0.0,
            shuffle: true,
        }
    }
}

/// Outcome of one subproblem solve.
#[derive(Clone, Copy, Debug)]
pub struct SubResult {
    /// Coordinate-descent passes used (Figure 2's y-axis).
    pub passes: usize,
    /// Final duality gap on the working set.
    pub gap: f64,
    pub converged: bool,
}

/// Mutable solve state threaded through the path driver. `eta = Xβ` and
/// `resid = y − μ(η)` are kept consistent with `beta` on exit.
pub struct SolveState {
    pub beta: Vec<f64>,
    pub eta: Vec<f64>,
    pub resid: Vec<f64>,
}

impl SolveState {
    pub fn new(n: usize, p: usize) -> Self {
        Self {
            beta: vec![0.0; p],
            eta: vec![0.0; n],
            resid: vec![0.0; n],
        }
    }

    /// Recompute η = Xβ and the pseudo-residual from scratch.
    pub fn refresh<D: Design + ?Sized>(&mut self, design: &D, y: &[f64], loss: Loss) {
        self.eta.iter_mut().for_each(|v| *v = 0.0);
        for (j, &b) in self.beta.iter().enumerate() {
            if b != 0.0 {
                design.col_axpy(j, b, &mut self.eta);
            }
        }
        loss.pseudo_residual_into(y, &self.eta, &mut self.resid);
    }

    pub fn l1_norm(&self) -> f64 {
        blas::asum(&self.beta)
    }

    /// Support of β.
    pub fn active_set(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.active_set_into(&mut out);
        out
    }

    /// Support of β, written into a caller-owned buffer so the per-step
    /// path loop reuses one allocation instead of collecting a fresh
    /// `Vec` every step.
    pub fn active_set_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            self.beta
                .iter()
                .enumerate()
                .filter(|(_, b)| **b != 0.0)
                .map(|(j, _)| j),
        );
    }
}

/// Reusable solver buffers. One instance lives in the path driver's
/// [`Workspace`](crate::path::Workspace) and is threaded through every
/// subproblem solve; the buffers grow to the problem size once and are
/// then reused for the rest of the path.
#[derive(Default)]
pub struct SolverScratch {
    order: Vec<usize>,
    w: Vec<f64>,
    d_eta: Vec<f64>,
    weighted_resid: Vec<f64>,
    beta0: Vec<f64>,
    trial_eta: Vec<f64>,
    wx: Vec<f64>,
}

impl SolverScratch {
    /// Heap capacity held by the scratch, in bytes (profile accounting).
    pub fn capacity_bytes(&self) -> usize {
        8 * (self.order.capacity()
            + self.w.capacity()
            + self.d_eta.capacity()
            + self.weighted_resid.capacity()
            + self.beta0.capacity()
            + self.trial_eta.capacity()
            + self.wx.capacity())
    }
}

/// Solve the subproblem restricted to `working`. Returns pass count and
/// final gap. `col_sq_norms[j]` must hold ‖xⱼ‖² for j ∈ working.
///
/// Allocates its own [`SolverScratch`]; the path driver calls
/// [`solve_subproblem_with`] instead to reuse one scratch across steps.
#[allow(clippy::too_many_arguments)]
pub fn solve_subproblem<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    lambda: f64,
    working: &[usize],
    state: &mut SolveState,
    col_sq_norms: &[f64],
    zeta: f64,
    settings: &CdSettings,
    rng: &mut Xoshiro256pp,
) -> SubResult {
    let mut scratch = SolverScratch::default();
    solve_subproblem_with(
        design,
        y,
        loss,
        lambda,
        working,
        state,
        col_sq_norms,
        zeta,
        settings,
        rng,
        &mut scratch,
    )
}

/// [`solve_subproblem`] with caller-owned scratch buffers.
#[allow(clippy::too_many_arguments)]
pub fn solve_subproblem_with<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    lambda: f64,
    working: &[usize],
    state: &mut SolveState,
    col_sq_norms: &[f64],
    zeta: f64,
    settings: &CdSettings,
    rng: &mut Xoshiro256pp,
    scratch: &mut SolverScratch,
) -> SubResult {
    match loss {
        Loss::Gaussian => solve_gaussian(
            design,
            y,
            lambda,
            working,
            state,
            col_sq_norms,
            zeta,
            settings,
            rng,
            scratch,
        ),
        _ => solve_glm(
            design,
            y,
            loss,
            lambda,
            working,
            state,
            zeta,
            settings,
            rng,
            scratch,
        ),
    }
}

/// Duality gap of the *working-set* problem at the current state
/// (Lemma 3.4's certificate: θ = resid / max(λ, ‖X_Wᵀ resid‖∞)).
pub fn working_gap<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    lambda: f64,
    working: &[usize],
    state: &SolveState,
) -> f64 {
    let mut xt_inf = 0.0f64;
    for &j in working {
        xt_inf = xt_inf.max(design.col_dot(j, &state.resid).abs());
    }
    loss.duality_gap(y, &state.eta, &state.resid, xt_inf, lambda, state.l1_norm())
}

#[allow(clippy::too_many_arguments)]
fn solve_gaussian<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    lambda: f64,
    working: &[usize],
    state: &mut SolveState,
    col_sq_norms: &[f64],
    zeta: f64,
    settings: &CdSettings,
    rng: &mut Xoshiro256pp,
    scratch: &mut SolverScratch,
) -> SubResult {
    let tol = settings.eps * zeta;
    // Maintain r = y − Xβ directly.
    state.refresh(design, y, Loss::Gaussian);
    let order = &mut scratch.order;
    order.clear();
    order.extend_from_slice(working);
    let mut passes = 0;

    loop {
        // Convergence check first: warm starts are often already optimal
        // (paper Fig. 2 counts 1 pass in that regime, so we check before
        // the first pass and count the epoch that confirms it).
        // CD below maintains `resid` only, so sync η = y − r before the
        // gap evaluation (the primal is computed from η).
        for i in 0..y.len() {
            state.eta[i] = y[i] - state.resid[i];
        }
        let gap = working_gap(design, y, Loss::Gaussian, lambda, working, state);
        if gap <= tol || passes >= settings.max_passes {
            return SubResult {
                passes: passes.max(1),
                gap,
                converged: gap <= tol,
            };
        }
        if settings.shuffle {
            rng.shuffle(order);
        }
        for &j in order.iter() {
            let vj = col_sq_norms[j];
            if vj <= 0.0 {
                continue;
            }
            let bj = state.beta[j];
            let g = design.col_dot(j, &state.resid);
            let u = g + vj * bj;
            let new = soft_threshold(u, lambda) / (vj + settings.phi);
            if new != bj {
                design.col_axpy(j, bj - new, &mut state.resid);
                state.beta[j] = new;
            }
        }
        passes += 1;
    }
}

#[allow(clippy::too_many_arguments)]
fn solve_glm<D: Design + ?Sized>(
    design: &D,
    y: &[f64],
    loss: Loss,
    lambda: f64,
    working: &[usize],
    state: &mut SolveState,
    zeta: f64,
    settings: &CdSettings,
    rng: &mut Xoshiro256pp,
    scratch: &mut SolverScratch,
) -> SubResult {
    let n = y.len();
    let tol = settings.eps * zeta;
    state.refresh(design, y, loss);
    let SolverScratch {
        order,
        w,
        d_eta,
        weighted_resid,
        beta0,
        trial_eta,
        wx,
    } = scratch;
    order.clear();
    order.extend_from_slice(working);
    let mut passes = 0;
    w.clear();
    w.resize(n, 0.0);
    d_eta.clear();
    d_eta.resize(n, 0.0);
    weighted_resid.clear();
    weighted_resid.resize(n, 0.0);

    loop {
        let gap = working_gap(design, y, loss, lambda, working, state);
        if gap <= tol || passes >= settings.max_passes {
            return SubResult {
                passes: passes.max(1),
                gap,
                converged: gap <= tol,
            };
        }

        // Build the local quadratic model at the current β (paper
        // §3.3.3): weights w = f″(η), gradient via the pseudo-residual.
        loss.weights_into(&state.eta, w);
        // Guard against vanishing curvature far in the tails.
        for wi in w.iter_mut() {
            *wi = wi.max(1e-10);
        }
        d_eta.iter_mut().for_each(|v| *v = 0.0);
        beta0.clear();
        beta0.extend(order.iter().map(|&j| state.beta[j]));

        // Inner CD epochs on the quadratic model.
        for _ in 0..settings.inner_epochs.max(1) {
            if settings.shuffle {
                rng.shuffle(order);
            }
            // weighted_resid = w ⊙ d_eta, updated incrementally below.
            for i in 0..n {
                weighted_resid[i] = w[i] * d_eta[i];
            }
            for &j in order.iter() {
                // h_j = xⱼᵀ D(w) xⱼ ; recomputed per epoch because w is
                // fixed within the quadratic model.
                let hj = design_weighted_sq_norm(design, j, w);
                if hj <= 0.0 {
                    continue;
                }
                let bj = state.beta[j];
                // smooth grad of model: −xⱼᵀresid + xⱼᵀ(w ⊙ d_eta)
                let g = -design.col_dot(j, &state.resid) + design.col_dot(j, weighted_resid);
                let u = hj * bj - g;
                let new = soft_threshold(u, lambda) / (hj + settings.phi);
                if new != bj {
                    let delta = new - bj;
                    // d_eta += delta * x_j ; weighted_resid += delta * w ⊙ x_j
                    design.col_axpy(j, delta, d_eta);
                    state.beta[j] = new;
                    // Correctness requires weighted_resid == w ⊙ d_eta, so
                    // update it exactly through the reusable `wx` buffer.
                    design_col_axpy_weighted(design, j, delta, w, weighted_resid, wx);
                }
            }
            passes += 1;
        }

        // Proximal-Newton step direction is Δη = d_eta (already includes
        // β updates). Line search on the true objective (Blitz).
        let mut alpha = 1.0;
        if settings.line_search {
            let p0 = loss.value(y, &state.eta) + lambda * state.l1_norm_with(order, beta0);
            let l1_new = state.l1_norm();
            trial_eta.clear();
            trial_eta.resize(n, 0.0);
            let mut accepted = false;
            for _ in 0..24 {
                for i in 0..n {
                    trial_eta[i] = state.eta[i] + alpha * d_eta[i];
                }
                // ℓ₁ norm along the segment interpolates ≤ linearly:
                // ‖β0 + α(β−β0)‖₁ ≤ (1−α)‖β0‖₁ + α‖β‖₁; using the convex
                // bound keeps the test conservative.
                let l1_alpha = (1.0 - alpha) * state.l1_norm_with(order, beta0) + alpha * l1_new;
                let p_trial = loss.value(y, trial_eta) + lambda * l1_alpha;
                if p_trial <= p0 + 1e-12 * p0.abs().max(1.0) {
                    accepted = true;
                    break;
                }
                alpha *= 0.5;
            }
            if !accepted {
                alpha = 0.0;
            }
        }

        if alpha == 1.0 {
            blas::axpy(1.0, d_eta, &mut state.eta);
        } else {
            // Scale β back toward β0 and rebuild η consistently.
            for (k, &j) in order.iter().enumerate() {
                state.beta[j] = beta0[k] + alpha * (state.beta[j] - beta0[k]);
            }
            blas::axpy(alpha, d_eta, &mut state.eta);
            if alpha == 0.0 {
                // Stalled: bail out with the current gap.
                loss.pseudo_residual_into(y, &state.eta, &mut state.resid);
                let gap = working_gap(design, y, loss, lambda, working, state);
                return SubResult {
                    passes: passes.max(1),
                    gap,
                    converged: gap <= tol,
                };
            }
        }
        loss.pseudo_residual_into(y, &state.eta, &mut state.resid);
    }
}

impl SolveState {
    /// ‖β‖₁ when the coordinates in `order` are replaced by `vals`.
    fn l1_norm_with(&self, order: &[usize], vals: &[f64]) -> f64 {
        let mut s = self.l1_norm();
        for (k, &j) in order.iter().enumerate() {
            s += vals[k].abs() - self.beta[j].abs();
        }
        s
    }
}

#[inline]
fn design_weighted_sq_norm<D: Design + ?Sized>(design: &D, j: usize, w: &[f64]) -> f64 {
    design.gram_weighted(j, j, Some(w))
}

/// v ← v + alpha · (w ⊙ xⱼ). Expressing w ⊙ xⱼ generically requires a
/// materialized column: axpy into a zeroed caller-owned buffer, then
/// fold through the weights. The buffer lives in [`SolverScratch`], so
/// the steady-state solve performs no allocation here.
#[inline]
fn design_col_axpy_weighted<D: Design + ?Sized>(
    design: &D,
    j: usize,
    alpha: f64,
    w: &[f64],
    v: &mut [f64],
    buf: &mut Vec<f64>,
) {
    if buf.len() < v.len() {
        buf.resize(v.len(), 0.0);
    }
    let scratch = &mut buf[..v.len()];
    scratch.iter_mut().for_each(|x| *x = 0.0);
    design.col_axpy(j, alpha, scratch);
    for i in 0..v.len() {
        // scratch is sparse for CSC columns, but we cannot see the
        // pattern here; the dense pass is the price of genericity.
        v[i] += w[i] * scratch[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DesignMatrix, SyntheticSpec};
    use crate::linalg::DenseMatrix;

    fn dense_problem(
        n: usize,
        p: usize,
        s: usize,
        loss: Loss,
        seed: u64,
    ) -> (DesignMatrix, Vec<f64>) {
        let mut spec = SyntheticSpec::new(n, p, s).seed(seed).snr(3.0).loss(loss);
        if matches!(loss, Loss::Poisson) {
            spec = spec.signal_scale(0.3);
        }
        let d = spec.generate();
        (d.design, d.response)
    }

    fn lambda_max<D: Design + ?Sized>(design: &D, y: &[f64], loss: Loss) -> f64 {
        let mut resid = vec![0.0; y.len()];
        let eta = vec![0.0; y.len()];
        loss.pseudo_residual_into(y, &eta, &mut resid);
        let mut m = 0.0f64;
        for j in 0..design.ncols() {
            m = m.max(design.col_dot(j, &resid).abs());
        }
        m
    }

    fn col_norms<D: Design + ?Sized>(design: &D) -> Vec<f64> {
        (0..design.ncols()).map(|j| design.col_sq_norm(j)).collect()
    }

    /// Max KKT violation over all predictors: for active j,
    /// |c_j − λ sign(β_j)|; for inactive, max(|c_j| − λ, 0).
    fn kkt_violation<D: Design + ?Sized>(
        design: &D,
        state: &SolveState,
        lambda: f64,
    ) -> f64 {
        let mut worst = 0.0f64;
        for j in 0..design.ncols() {
            let c = design.col_dot(j, &state.resid);
            if state.beta[j] != 0.0 {
                worst = worst.max((c - lambda * state.beta[j].signum()).abs());
            } else {
                worst = worst.max((c.abs() - lambda).max(0.0));
            }
        }
        worst
    }

    #[test]
    fn gaussian_full_working_set_satisfies_kkt() {
        let (x, y) = dense_problem(60, 30, 4, Loss::Gaussian, 1);
        let lmax = lambda_max(&x, &y, Loss::Gaussian);
        let lambda = 0.3 * lmax;
        let working: Vec<usize> = (0..30).collect();
        let mut state = SolveState::new(60, 30);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let norms = col_norms(&x);
        let settings = CdSettings {
            eps: 1e-8,
            ..Default::default()
        };
        let zeta = Loss::Gaussian.zeta(&y);
        let res = solve_subproblem(
            &x, &y, Loss::Gaussian, lambda, &working, &mut state, &norms, zeta, &settings,
            &mut rng,
        );
        assert!(res.converged, "gap {}", res.gap);
        assert!(
            kkt_violation(&x, &state, lambda) < 1e-3 * lambda,
            "kkt {}",
            kkt_violation(&x, &state, lambda)
        );
    }

    #[test]
    fn gaussian_lambda_max_gives_null_model() {
        let (x, y) = dense_problem(40, 20, 3, Loss::Gaussian, 2);
        let lmax = lambda_max(&x, &y, Loss::Gaussian);
        let working: Vec<usize> = (0..20).collect();
        let mut state = SolveState::new(40, 20);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let norms = col_norms(&x);
        let res = solve_subproblem(
            &x,
            &y,
            Loss::Gaussian,
            lmax * 1.0001,
            &working,
            &mut state,
            &norms,
            Loss::Gaussian.zeta(&y),
            &CdSettings::default(),
            &mut rng,
        );
        assert!(res.converged);
        assert!(state.beta.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn gaussian_matches_cholesky_solution_on_active_set() {
        // With a fixed (correct) active set and sign vector, the lasso
        // solution is (XᵀX)⁻¹(Xᵀy − λ sign) — Theorem 3.1's basis.
        let (x, y) = dense_problem(80, 10, 2, Loss::Gaussian, 3);
        let lambda = 0.1 * lambda_max(&x, &y, Loss::Gaussian);
        let working: Vec<usize> = (0..10).collect();
        let mut state = SolveState::new(80, 10);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let norms = col_norms(&x);
        let settings = CdSettings {
            eps: 1e-10,
            ..Default::default()
        };
        let res = solve_subproblem(
            &x,
            &y,
            Loss::Gaussian,
            lambda,
            &working,
            &mut state,
            &norms,
            Loss::Gaussian.zeta(&y),
            &settings,
            &mut rng,
        );
        assert!(res.converged);
        let active = state.active_set();
        assert!(!active.is_empty());
        // closed form on the active set
        let xd = match &x {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!(),
        };
        let xa = xd.select_cols(&active);
        let h = xa.t_gemm(&xa);
        let mut rhs = vec![0.0; active.len()];
        xa.t_gemv_dense(&y, &mut rhs);
        for (k, &j) in active.iter().enumerate() {
            rhs[k] -= lambda * state.beta[j].signum();
        }
        let sol = crate::linalg::cholesky::Cholesky::factor(&h).unwrap().solve(&rhs);
        for (k, &j) in active.iter().enumerate() {
            assert!(
                (state.beta[j] - sol[k]).abs() < 1e-5,
                "beta[{j}]={} vs {}",
                state.beta[j],
                sol[k]
            );
        }
    }

    #[test]
    fn logistic_converges_and_satisfies_kkt() {
        let (x, y) = dense_problem(100, 25, 4, Loss::Logistic, 4);
        let lmax = lambda_max(&x, &y, Loss::Logistic);
        let lambda = 0.2 * lmax;
        let working: Vec<usize> = (0..25).collect();
        let mut state = SolveState::new(100, 25);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let norms = col_norms(&x);
        let settings = CdSettings {
            eps: 1e-7,
            ..Default::default()
        };
        let res = solve_subproblem(
            &x,
            &y,
            Loss::Logistic,
            lambda,
            &working,
            &mut state,
            &norms,
            Loss::Logistic.zeta(&y),
            &settings,
            &mut rng,
        );
        assert!(res.converged, "gap {}", res.gap);
        assert!(
            kkt_violation(&x, &state, lambda) < 1e-2 * lambda,
            "kkt {}",
            kkt_violation(&x, &state, lambda)
        );
    }

    #[test]
    fn poisson_converges() {
        let (x, y) = dense_problem(120, 15, 3, Loss::Poisson, 5);
        let lmax = lambda_max(&x, &y, Loss::Poisson);
        let lambda = 0.3 * lmax;
        let working: Vec<usize> = (0..15).collect();
        let mut state = SolveState::new(120, 15);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let norms = col_norms(&x);
        let settings = CdSettings {
            eps: 1e-6,
            ..Default::default()
        };
        let res = solve_subproblem(
            &x,
            &y,
            Loss::Poisson,
            lambda,
            &working,
            &mut state,
            &norms,
            Loss::Poisson.zeta(&y),
            &settings,
            &mut rng,
        );
        assert!(res.converged, "gap {}", res.gap);
    }

    #[test]
    fn restricted_working_set_leaves_others_zero() {
        let (x, y) = dense_problem(50, 20, 5, Loss::Gaussian, 6);
        let lambda = 0.1 * lambda_max(&x, &y, Loss::Gaussian);
        let working = vec![2, 7, 11];
        let mut state = SolveState::new(50, 20);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let norms = col_norms(&x);
        solve_subproblem(
            &x,
            &y,
            Loss::Gaussian,
            lambda,
            &working,
            &mut state,
            &norms,
            Loss::Gaussian.zeta(&y),
            &CdSettings::default(),
            &mut rng,
        );
        for j in 0..20 {
            if !working.contains(&j) {
                assert_eq!(state.beta[j], 0.0);
            }
        }
    }

    #[test]
    fn warm_start_needs_fewer_passes() {
        let (x, y) = dense_problem(100, 40, 5, Loss::Gaussian, 7);
        let lmax = lambda_max(&x, &y, Loss::Gaussian);
        let working: Vec<usize> = (0..40).collect();
        let norms = col_norms(&x);
        let zeta = Loss::Gaussian.zeta(&y);
        let settings = CdSettings::default();
        // Cold solve at 0.5 λmax.
        let mut cold = SolveState::new(100, 40);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let r1 = solve_subproblem(
            &x, &y, Loss::Gaussian, 0.5 * lmax, &working, &mut cold, &norms, zeta, &settings,
            &mut rng,
        );
        // Re-solve at the *same* λ warm: should take ~1 pass.
        let r2 = solve_subproblem(
            &x, &y, Loss::Gaussian, 0.5 * lmax, &working, &mut cold, &norms, zeta, &settings,
            &mut rng,
        );
        assert!(r2.passes <= 2, "warm restart passes {}", r2.passes);
        assert!(r1.passes >= r2.passes);
    }

    #[test]
    fn sparse_design_solves_too() {
        let d = SyntheticSpec::new(80, 60, 5)
            .density(0.1)
            .seed(8)
            .generate();
        let lambda = 0.3 * lambda_max(&d.design, &d.response, Loss::Gaussian);
        let working: Vec<usize> = (0..60).collect();
        let mut state = SolveState::new(80, 60);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let norms = col_norms(&d.design);
        let res = solve_subproblem(
            &d.design,
            &d.response,
            Loss::Gaussian,
            lambda,
            &working,
            &mut state,
            &norms,
            Loss::Gaussian.zeta(&d.response),
            &CdSettings::default(),
            &mut rng,
        );
        assert!(res.converged);
        assert!(kkt_violation(&d.design, &state, lambda) < 1e-2 * lambda);
    }

    #[test]
    fn elastic_net_shrinks_more() {
        let (x, y) = dense_problem(60, 20, 4, Loss::Gaussian, 9);
        let lambda = 0.2 * lambda_max(&x, &y, Loss::Gaussian);
        let working: Vec<usize> = (0..20).collect();
        let norms = col_norms(&x);
        let zeta = Loss::Gaussian.zeta(&y);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut lasso = SolveState::new(60, 20);
        solve_subproblem(
            &x, &y, Loss::Gaussian, lambda, &working, &mut lasso, &norms, zeta,
            &CdSettings::default(), &mut rng,
        );
        let mut enet = SolveState::new(60, 20);
        let settings = CdSettings {
            phi: 50.0,
            ..Default::default()
        };
        // Elastic-net KKT differs; we only check the shrinkage effect.
        solve_subproblem(
            &x, &y, Loss::Gaussian, lambda, &working, &mut enet, &norms, zeta, &settings,
            &mut rng,
        );
        assert!(enet.l1_norm() < lasso.l1_norm());
    }

    #[test]
    fn refresh_consistency() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let x = DesignMatrix::Dense(m);
        let y = vec![3.0, 4.0];
        let mut st = SolveState::new(2, 2);
        st.beta = vec![1.0, 0.5];
        st.refresh(&x, &y, Loss::Gaussian);
        assert_eq!(st.eta, vec![1.0, 1.0]);
        assert_eq!(st.resid, vec![2.0, 3.0]);
        assert_eq!(st.active_set(), vec![0, 1]);
    }
}
