//! Experiment harness: one module per table/figure of the paper's
//! evaluation (§4 and Appendix F). Each regenerates the corresponding
//! rows/series — workload generation, method sweep, repetitions, 95%
//! CIs — and both prints a table and (optionally) writes CSV into a
//! results directory. DESIGN.md §5 maps every experiment id to its
//! module; EXPERIMENTS.md records paper-vs-measured outcomes.
//!
//! Scaling: the paper's largest designs do not fit this session's
//! budget, so every experiment has a `quick` (default) and `full`
//! preset; `full` is paper-scale. Comparisons are *relative across
//! methods on identical inputs*, which is the quantity the paper
//! reports, so the preset affects absolute seconds only.

pub mod ablation;
pub mod breakdown;
pub mod gamma;
pub mod gap_safe_ablation;
pub mod path_length;
pub mod poisson;
pub mod real_data;
pub mod safe_rules;
pub mod screening_counts;
pub mod simulated_timing;
pub mod tolerance;
pub mod warm_starts;

use crate::coordinator::Coordinator;
use crate::data::{Dataset, SyntheticSpec};
use crate::loss::Loss;
use crate::metrics::Table;
use crate::path::{PathFit, PathFitter, PathSettings};
use crate::rng::derive_seed;
use crate::screening::ScreeningKind;
use std::path::{Path, PathBuf};

/// Shared experiment configuration (from the CLI).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Repetitions per cell (paper: 20 small / 3 large).
    pub reps: usize,
    /// Paper-scale sizes when true; scaled-down defaults otherwise.
    pub full: bool,
    /// Where to write CSVs (None = print only).
    pub out_dir: Option<PathBuf>,
    pub threads: usize,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            reps: 3,
            full: false,
            out_dir: None,
            threads: Coordinator::auto().threads,
            seed: 0x9E15,
        }
    }
}

impl ExpConfig {
    pub fn coordinator(&self) -> Coordinator {
        Coordinator::new(self.threads)
    }

    /// Seed for repetition `rep` of cell `cell`.
    pub fn cell_seed(&self, cell: u64, rep: u64) -> u64 {
        derive_seed(self.seed, cell.wrapping_mul(1009) ^ rep)
    }

    /// High-dimensional scenario size (§4.1: n=400, p=40 000, s=20).
    pub fn high_dim(&self) -> (usize, usize, usize) {
        if self.full {
            (400, 40_000, 20)
        } else {
            (100, 5_000, 10)
        }
    }

    /// The n=200, p=20 000 appendix scenario (F.1–F.4, F.8).
    pub fn appendix_dim(&self) -> (usize, usize, usize) {
        if self.full {
            (200, 20_000, 20)
        } else {
            (100, 4_000, 10)
        }
    }

    /// Low-dimensional scenario (§4.1: n=10 000, p=100, s=5).
    pub fn low_dim(&self) -> (usize, usize, usize) {
        if self.full {
            (10_000, 100, 5)
        } else {
            (2_000, 100, 5)
        }
    }
}

/// Default path settings used by all experiments (paper §4 defaults).
pub fn paper_settings() -> PathSettings {
    PathSettings::default()
}

/// Generate the §4.1 simulated scenario.
pub fn simulate(
    n: usize,
    p: usize,
    s: usize,
    rho: f64,
    snr: f64,
    loss: Loss,
    seed: u64,
) -> Dataset {
    let mut spec = SyntheticSpec::new(n, p, s)
        .rho(rho)
        .snr(snr)
        .loss(loss)
        .seed(seed);
    if matches!(loss, Loss::Poisson) {
        spec = spec.signal_scale(1.0 / (s as f64).sqrt().max(1.0));
    } else if matches!(loss, Loss::Logistic) {
        spec = spec.signal_scale(2.0 / (s as f64).sqrt().max(1.0));
    }
    spec.generate()
}

/// Fit a path and return (fit, wall seconds).
pub fn fit_timed(
    data: &Dataset,
    kind: ScreeningKind,
    settings: &PathSettings,
) -> (PathFit, f64) {
    let fitter = PathFitter::new(data.loss, kind).with_settings(settings.clone());
    let t = std::time::Instant::now();
    let fit = fitter.fit(&data.design, &data.response);
    let secs = t.elapsed().as_secs_f64();
    (fit, secs)
}

/// Write a table as CSV into the configured output directory.
pub fn write_csv(cfg: &ExpConfig, name: &str, table: &Table) {
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir).expect("creating results dir");
        let path: PathBuf = dir.join(format!("{name}.csv"));
        std::fs::write(&path, table.to_csv()).expect("writing csv");
        eprintln!("  wrote {}", path.display());
    }
}

/// Write arbitrary text (long-form per-step series).
pub fn write_text(cfg: &ExpConfig, name: &str, text: &str) {
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir).expect("creating results dir");
        let path = dir.join(name);
        std::fs::write(&path, text).expect("writing file");
        eprintln!("  wrote {}", path.display());
    }
}

/// The four main-paper methods (Fig. 3, Table 1).
pub fn main_methods() -> Vec<ScreeningKind> {
    vec![
        ScreeningKind::Hessian,
        ScreeningKind::Working,
        ScreeningKind::Blitz,
        ScreeningKind::Celer,
    ]
}

/// Named experiment registry for the CLI (`hx exp <name>`).
pub fn run_experiment(name: &str, cfg: &ExpConfig) -> Result<(), String> {
    match name {
        "fig1" | "fig7" => screening_counts::run_counts(cfg),
        "tab3" => screening_counts::run_violations(cfg),
        "fig2" => warm_starts::run(cfg),
        "fig3" => simulated_timing::run(cfg),
        "tab1" | "tab4" => real_data::run(cfg),
        "fig4" => path_length::run(cfg),
        "fig5" => tolerance::run(cfg),
        "fig6" => gap_safe_ablation::run(cfg),
        "fig8" => safe_rules::run(cfg),
        "fig9" => gamma::run(cfg),
        "fig10" => ablation::run(cfg),
        "fig11" => poisson::run(cfg),
        "fig12" | "fig13" | "fig14" => breakdown::run(cfg),
        "all" => {
            for e in EXPERIMENTS {
                eprintln!("=== {e} ===");
                run_experiment(e, cfg)?;
            }
            Ok(())
        }
        other => Err(format!(
            "unknown experiment '{other}'; known: {} or 'all'",
            EXPERIMENTS.join(", ")
        )),
    }
}

/// Canonical experiment list (order = DESIGN.md §5).
pub const EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig3", "tab1", "fig4", "fig5", "fig6", "tab3", "fig8", "fig9", "fig10",
    "fig11", "fig12",
];

/// Is `path` the repo's artifacts dir with a manifest present?
pub fn artifacts_available() -> bool {
    Path::new("artifacts/manifest.tsv").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_presets() {
        let quick = ExpConfig::default();
        let full = ExpConfig {
            full: true,
            ..Default::default()
        };
        assert_eq!(full.high_dim(), (400, 40_000, 20));
        assert_eq!(full.low_dim(), (10_000, 100, 5));
        assert!(quick.high_dim().1 < full.high_dim().1);
        assert_eq!(full.appendix_dim(), (200, 20_000, 20));
    }

    #[test]
    fn cell_seeds_differ() {
        let cfg = ExpConfig::default();
        assert_ne!(cfg.cell_seed(0, 0), cfg.cell_seed(0, 1));
        assert_ne!(cfg.cell_seed(0, 0), cfg.cell_seed(1, 0));
        assert_eq!(cfg.cell_seed(2, 3), cfg.cell_seed(2, 3));
    }

    #[test]
    fn unknown_experiment_is_error() {
        let cfg = ExpConfig::default();
        assert!(run_experiment("nope", &cfg).is_err());
    }

    #[test]
    fn registry_covers_design_md_index() {
        for e in EXPERIMENTS {
            // must dispatch without the "unknown" error (we don't run
            // them here — that is the integration suite's job)
            assert!(!e.is_empty());
        }
        assert_eq!(EXPERIMENTS.len(), 13);
    }
}
