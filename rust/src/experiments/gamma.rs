//! Appendix F.7 (Figure 9): sensitivity to γ — the fraction of the
//! strong rule's unit bound mixed into the Hessian estimate. Sweeps
//! γ ∈ [0.001, 0.3], recording screened counts, violations and time
//! (relative per ρ level, as in the paper's figure).

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let gammas = [0.001, 0.003, 0.01, 0.03, 0.1, 0.3];
    let (n, p, s) = cfg.high_dim();
    struct Cell {
        gamma: f64,
        rho: f64,
        rep: u64,
    }
    let mut cells = Vec::new();
    for &gamma in &gammas {
        for &rho in &[0.0, 0.4, 0.8] {
            for rep in 0..cfg.reps as u64 {
                cells.push(Cell { gamma, rho, rep });
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig9", cells, |_, c| {
        let data = simulate(n, p, s, c.rho, 2.0, Loss::Gaussian, cfg.cell_seed(5_000, c.rep));
        let mut settings = paper_settings();
        settings.gamma = c.gamma;
        let (fit, secs) = fit_timed(&data, ScreeningKind::Hessian, &settings);
        let steps = fit.steps.len().max(1) as f64;
        (
            c.gamma,
            c.rho,
            fit.mean_screened(),
            fit.total_violations() as f64 / steps,
            secs,
        )
    });

    let mut table = Table::new(&["gamma", "rho", "Screened", "Violations", "Rel. time"]);
    for &rho in &[0.0, 0.4, 0.8] {
        // relative to the mean over γ at this ρ (paper's normalization)
        let rho_times: Vec<f64> = results
            .iter()
            .filter(|r| r.1 == rho)
            .map(|r| r.4)
            .collect();
        let rho_mean = rho_times.iter().sum::<f64>() / rho_times.len().max(1) as f64;
        for &gamma in &gammas {
            let rows: Vec<_> = results
                .iter()
                .filter(|r| r.0 == gamma && r.1 == rho)
                .collect();
            let scr = Summary::of(&rows.iter().map(|r| r.2).collect::<Vec<_>>());
            let vio = Summary::of(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
            let t = Summary::of(&rows.iter().map(|r| r.4).collect::<Vec<_>>());
            table.row(vec![
                format!("{gamma}"),
                format!("{rho}"),
                format!("{}", sig_figs(scr.mean, 4)),
                format!("{}", sig_figs(vio.mean, 3)),
                format!("{}", sig_figs(t.mean / rho_mean, 3)),
            ]);
        }
    }
    println!("\nFigure 9 — γ sweep (screened, violations, relative time)");
    println!("{}", table.render());
    write_csv(cfg, "fig9_gamma", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn larger_gamma_fewer_violations_more_screened() {
        let mk = |gamma: f64| {
            let data = simulate(60, 1_000, 8, 0.8, 2.0, Loss::Gaussian, 10);
            let mut settings = paper_settings();
            settings.gamma = gamma;
            fit_timed(&data, ScreeningKind::Hessian, &settings).0
        };
        let small = mk(0.0);
        let large = mk(0.3);
        assert!(
            large.total_violations() <= small.total_violations(),
            "violations: γ=0.3 {} vs γ=0 {}",
            large.total_violations(),
            small.total_violations()
        );
        assert!(
            large.mean_screened() >= small.mean_screened(),
            "screened: γ=0.3 {} vs γ=0 {}",
            large.mean_screened(),
            small.mean_screened()
        );
    }
}
