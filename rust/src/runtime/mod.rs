//! Compute-backend runtime for the solve path's full KKT sweeps.
//!
//! The path driver ([`crate::path::PathFitter::fit_with_engine`]) can
//! route its hot full-set operations — the correlation sweep c = Xᵀr,
//! the fused KKT sweep, the *batched look-ahead* sweep across several
//! upcoming λ values, and the weighted Gram panels of Algorithm 1 —
//! through a [`Backend`]:
//!
//! * [`NativeBackend`] (always available, the default): pure-Rust f64
//!   kernels on top of [`crate::linalg`], with chunked column-parallel
//!   execution (`std::thread::scope`, zero dependencies) behind a
//!   `threads` knob. Exact — the reference implementation every other
//!   backend is checked against.
//! * `PjrtBackend` (behind the **`pjrt`** cargo feature): executes the
//!   AOT artifacts produced by `python/compile/aot.py` (HLO text) on a
//!   PJRT client. The engine code type-checks against the in-tree
//!   `xla_stub` shim, so no XLA toolchain is needed to *build*;
//!   wiring a real `xla`-crate client in is a linking concern, not an
//!   API one (see README "Feature matrix").
//! * [`ShardedBackend`]: contiguous column shards, each owned by its
//!   own inner backend (N native engines today, PJRT devices later),
//!   with double-buffered pipelined shard uploads and a reduction
//!   layer that merges per-shard results into bit-identical global
//!   answers (see [`shard`]'s module docs for the contracts).
//!
//! Precision contract: backends may compute in f32 (the AOT artifacts
//! do). [`EngineSweep::full_sweep`] therefore re-verifies every
//! *borderline* correlation (within `recheck_band` of the screening
//! threshold) with the native f64 path, so KKT decisions never depend
//! on reduced-precision rounding. [`EngineSweep::look_ahead`] applies
//! the same policy across the whole λ batch and rebuilds the keep
//! masks from the corrected correlations.

use crate::error::Result;
use crate::linalg::Design;
use crate::loss::Loss;
use std::path::Path;

mod native;
#[cfg(feature = "pjrt")]
mod pjrt;
mod shard;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use shard::{ShardedBackend, ShardedDesignView, UploadStats};

use crate::storage::ColumnSource;

/// A design registered with (uploaded to) a backend. Holds the
/// backend-specific representation plus the logical shape.
pub struct RegisteredDesign {
    pub n: usize,
    pub p: usize,
    /// ‖xⱼ‖₂ per column, cached at registration in f64 (the look-ahead
    /// sphere tests need them on every batched sweep).
    pub(crate) col_norms: Vec<f64>,
    pub(crate) repr: DesignRepr,
}

pub(crate) enum DesignRepr {
    /// Column-major (n, p) f64 copy owned by the native backend.
    Native(Vec<f64>),
    #[cfg(feature = "pjrt")]
    Pjrt(xla_stub::PjRtBuffer),
    /// Per-shard sub-designs behind the pipelined upload slots.
    Sharded(shard::ShardedRepr),
}

/// Result of a batched look-ahead KKT sweep: the correlation vector
/// and pseudo-residual at the evaluation point, plus one keep-mask per
/// requested λ. `keep[l][j] == false` certifies predictor j inactive
/// at `lambdas[l]` (Gap-Safe sphere test from this iterate's dual
/// point — see [`crate::screening::lookahead_keep`]), so the path
/// driver may skip it in that step's KKT check.
#[derive(Default)]
pub struct KktBatch {
    pub c: Vec<f64>,
    pub resid: Vec<f64>,
    pub keep: Vec<Vec<bool>>,
}

/// Reusable buffers for the `_into` sweep surfaces: one per fit, owned
/// by the caller (the path driver's workspace), written fresh by every
/// sweep. Keeping them out of [`EngineSweep`] keeps that type `&self`-
/// shareable; keeping them out of the backends keeps backends
/// stateless.
#[derive(Default)]
pub struct SweepScratch {
    /// Backend-side correlation vector (pre-recheck).
    pub c: Vec<f64>,
    /// Backend-side pseudo-residual.
    pub resid: Vec<f64>,
    /// Batched look-ahead sweep result.
    pub batch: KktBatch,
}

impl KktBatch {
    /// Heap capacity held by the batch, in bytes (profile accounting).
    pub fn capacity_bytes(&self) -> usize {
        8 * (self.c.capacity() + self.resid.capacity())
            + self.keep.capacity() * std::mem::size_of::<Vec<bool>>()
            + self.keep.iter().map(|m| m.capacity()).sum::<usize>()
    }
}

impl SweepScratch {
    /// Heap capacity held by the scratch, in bytes (profile accounting).
    pub fn capacity_bytes(&self) -> usize {
        8 * (self.c.capacity() + self.resid.capacity()) + self.batch.capacity_bytes()
    }
}

/// The operations a compute backend provides to the path driver.
///
/// Every method that depends on a compiled artifact returns
/// `Ok(None)` when the backend has nothing for the requested
/// (op, shape); the caller then falls back to the native sweep.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Number of ops this backend can serve (compiled artifacts for
    /// PJRT; the fixed native op set otherwise).
    fn num_ops(&self) -> usize;

    /// Number of worker threads the backend's kernels use (1 = serial).
    fn threads(&self) -> usize {
        1
    }

    /// Number of column shards the backend splits designs into
    /// (1 = unsharded).
    fn shards(&self) -> usize {
        1
    }

    /// Upload-pipeline counters, for backends that stage designs
    /// asynchronously. `None` for synchronous backends.
    fn upload_stats(&self) -> Option<UploadStats> {
        None
    }

    /// Whether a fused KKT sweep is available for this loss and shape.
    fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool;

    /// Whether this backend computes in exact f64. Exact backends skip
    /// the borderline re-verification in [`EngineSweep::full_sweep`];
    /// reduced-precision backends (f32 artifacts) must leave this
    /// false.
    fn is_exact(&self) -> bool {
        false
    }

    /// Register a design from its raw column-major f64 buffer.
    /// O(np), once per dataset.
    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign>;

    /// Register a design pulled from a [`ColumnSource`] (an `.hxd`
    /// file, a resident buffer, …). The default materializes the full
    /// design once and defers to [`Backend::register_design`] —
    /// correct for resident backends, which hold a full copy anyway.
    /// [`ShardedBackend`] overrides this with the streaming pipeline,
    /// where panels are pulled shard-by-shard and the full design is
    /// never materialized in one allocation.
    fn register_source(&self, mut source: Box<dyn ColumnSource>) -> Result<RegisteredDesign> {
        let (n, p) = (source.n(), source.p());
        let data = source.read_cols(0, p)?;
        self.register_design(&data, n, p)
    }

    /// c = Xᵀr. `None` when the backend has no kernel for this shape.
    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>>;

    /// Allocation-reusing twin of [`Backend::correlation`]: writes into
    /// a caller-owned buffer (resized as needed) and returns whether a
    /// kernel served the request. The default routes through the
    /// allocating method and moves the result into `c` — correct for
    /// every backend; [`NativeBackend`] overrides it with a true
    /// in-place kernel so the steady-state path loop allocates nothing.
    fn correlation_into(
        &self,
        design: &RegisteredDesign,
        r: &[f64],
        c: &mut Vec<f64>,
    ) -> Result<bool> {
        match self.correlation(design, r)? {
            Some(v) => {
                *c = v;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Fused KKT sweep: returns (c, pseudo-residual) at the given
    /// linear predictor, or `None` when unavailable for this
    /// (loss, shape).
    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>>;

    /// Allocation-reusing twin of [`Backend::kkt_sweep`] — same default
    /// shim / native-override split as [`Backend::correlation_into`].
    #[allow(clippy::too_many_arguments)]
    fn kkt_sweep_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        match self.kkt_sweep(loss, design, y, eta, lambda)? {
            Some((cv, rv)) => {
                *c = cv;
                *resid = rv;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Row-masked fused KKT sweep — the cross-validation fold kernel.
    /// `rows` are global row indices into the registered design (a CV
    /// training fold); `y`/`eta` are *compact* (length `rows.len()`),
    /// matching the fold view the path driver fits against. Returns
    /// (c, pseudo-residual) with `c` over all p columns and the
    /// residual compact, or `None` when the backend has no masked
    /// kernel for this (loss, shape) — the caller then falls back to
    /// the host-side fold-view sweep.
    ///
    /// Bitwise contract: implementations must gather the kept rows of
    /// each column into a compact buffer and reduce with the same
    /// `blas` kernels [`crate::cv::FoldView`] uses, so engine-routed
    /// fold fits are bit-identical to host-path fold fits.
    fn kkt_sweep_masked(
        &self,
        _loss: Loss,
        _design: &RegisteredDesign,
        _rows: &[usize],
        _y: &[f64],
        _eta: &[f64],
        _lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        Ok(None)
    }

    /// Allocation-reusing twin of [`Backend::kkt_sweep_masked`] — same
    /// default shim / native-override split as
    /// [`Backend::correlation_into`].
    #[allow(clippy::too_many_arguments)]
    fn kkt_sweep_masked_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        match self.kkt_sweep_masked(loss, design, rows, y, eta, lambda)? {
            Some((cv, rv)) => {
                *c = cv;
                *resid = rv;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Batched look-ahead KKT sweep (Larsson, "Look-Ahead Screening
    /// Rules for the Lasso", 2021): one correlation sweep at the
    /// current iterate serves screening tests at several upcoming λ
    /// values at once. `l1_norm` is ‖β‖₁ at the iterate (needed for
    /// the per-λ duality gaps). Default: unavailable — callers fall
    /// back to per-λ sequential sweeps.
    fn kkt_sweep_batch(
        &self,
        _loss: Loss,
        _design: &RegisteredDesign,
        _y: &[f64],
        _eta: &[f64],
        _lambdas: &[f64],
        _l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        Ok(None)
    }

    /// Allocation-reusing twin of [`Backend::kkt_sweep_batch`] — same
    /// default shim / native-override split as
    /// [`Backend::correlation_into`].
    #[allow(clippy::too_many_arguments)]
    fn kkt_sweep_batch_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
        batch: &mut KktBatch,
    ) -> Result<bool> {
        match self.kkt_sweep_batch(loss, design, y, eta, lambdas, l1_norm)? {
            Some(b) => {
                *batch = b;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Weighted Gram panel X_E D(w) X_Dᵀ (row-major (e, d)), the
    /// Algorithm-1 augmentation block. `xe_t`/`xd_t` are (e, n)/(d, n)
    /// row-major f64 slices; `w = None` means unit weights.
    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>>;

    /// Allocation-reusing twin of [`Backend::gram_block`] — same
    /// default shim / native-override split as
    /// [`Backend::correlation_into`].
    #[allow(clippy::too_many_arguments)]
    fn gram_block_into(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
        out: &mut Vec<f64>,
    ) -> Result<bool> {
        match self.gram_block(xe_t, w, xd_t, e, d, n)? {
            Some(v) => {
                *out = v;
                Ok(true)
            }
            None => Ok(false),
        }
    }
}

/// The runtime engine: a [`Backend`] behind a stable, object-safe
/// front the rest of the crate (path driver, CLI, benches) talks to.
pub struct RuntimeEngine {
    backend: Box<dyn Backend>,
}

impl std::fmt::Debug for RuntimeEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RuntimeEngine")
            .field("backend", &self.backend_name())
            .field("threads", &self.threads())
            .finish()
    }
}

impl RuntimeEngine {
    /// The pure-Rust backend. Always available, needs no artifacts.
    /// Serial kernels; see [`Self::native_threaded`] for the parallel
    /// variant.
    pub fn native() -> Self {
        Self {
            backend: Box::new(NativeBackend::default()),
        }
    }

    /// The pure-Rust backend with chunked column-parallel kernels.
    /// `threads == 0` selects the machine's available parallelism.
    /// Results are bit-identical at any thread count (parallelism is
    /// over whole columns / panel rows).
    pub fn native_threaded(threads: usize) -> Self {
        Self {
            backend: Box::new(NativeBackend::new(threads)),
        }
    }

    /// Column-sharded native execution: `shards` engines with
    /// `threads_per_shard` workers each, with pipelined shard uploads.
    /// Bit-identical to [`Self::native`] at any shard count (the
    /// reduction layer preserves the per-column scalar kernels — see
    /// [`ShardedBackend`]).
    pub fn native_sharded(shards: usize, threads_per_shard: usize) -> Self {
        Self {
            backend: Box::new(ShardedBackend::native(shards, threads_per_shard)),
        }
    }

    /// Wrap an arbitrary backend implementation.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        Self { backend }
    }

    /// Load and compile every AOT artifact listed in `dir`/manifest.tsv
    /// (PJRT). Without the `pjrt` feature this always errors: the
    /// default build ships no artifact executor, only [`Self::native`].
    #[cfg(feature = "pjrt")]
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Ok(Self {
            backend: Box::new(pjrt::PjrtBackend::load_dir(dir)?),
        })
    }

    /// See the `pjrt`-enabled variant; this build has no PJRT engine.
    #[cfg(not(feature = "pjrt"))]
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Err(crate::err!(
            "built without the `pjrt` feature: cannot load artifacts from {} \
             (use RuntimeEngine::native(), or rebuild with --features pjrt)",
            dir.display()
        ))
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Self> {
        Self::load_dir(Path::new("artifacts"))
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn num_ops(&self) -> usize {
        self.backend.num_ops()
    }

    /// Worker threads the backend's kernels use (1 = serial).
    pub fn threads(&self) -> usize {
        self.backend.threads()
    }

    /// Column shards the backend splits designs into (1 = unsharded).
    pub fn shards(&self) -> usize {
        self.backend.shards()
    }

    /// Upload-pipeline counters (`None` for synchronous backends).
    pub fn upload_stats(&self) -> Option<UploadStats> {
        self.backend.upload_stats()
    }

    /// Whether a KKT sweep is available for this loss and shape.
    pub fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        self.backend.supports_sweep(loss, n, p)
    }

    /// Whether the backend computes in exact f64.
    pub fn is_exact(&self) -> bool {
        self.backend.is_exact()
    }

    /// Upload a design (as its raw column-major f64 buffer).
    pub fn register_design(
        &self,
        col_major: &[f64],
        n: usize,
        p: usize,
    ) -> Result<RegisteredDesign> {
        self.backend.register_design(col_major, n, p)
    }

    /// Register a design from a [`ColumnSource`] — the out-of-core
    /// entry point (`.hxd` files stream shard panels from disk).
    pub fn register_source(&self, source: Box<dyn ColumnSource>) -> Result<RegisteredDesign> {
        self.backend.register_source(source)
    }

    /// c = Xᵀr; `None` when no kernel matches the shape.
    pub fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        self.backend.correlation(design, r)
    }

    /// Buffer-reusing correlation sweep (see
    /// [`Backend::correlation_into`]).
    pub fn correlation_into(
        &self,
        design: &RegisteredDesign,
        r: &[f64],
        c: &mut Vec<f64>,
    ) -> Result<bool> {
        self.backend.correlation_into(design, r, c)
    }

    /// Fused KKT sweep; `None` when unavailable for (loss, shape).
    pub fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        self.backend.kkt_sweep(loss, design, y, eta, lambda)
    }

    /// Buffer-reusing fused KKT sweep (see [`Backend::kkt_sweep_into`]).
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        self.backend
            .kkt_sweep_into(loss, design, y, eta, lambda, c, resid)
    }

    /// Row-masked fused KKT sweep over a fold's kept rows; `None` when
    /// the backend has no masked kernel (see
    /// [`Backend::kkt_sweep_masked`]).
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep_masked(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        self.backend
            .kkt_sweep_masked(loss, design, rows, y, eta, lambda)
    }

    /// Buffer-reusing row-masked KKT sweep (see
    /// [`Backend::kkt_sweep_masked_into`]).
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep_masked_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        self.backend
            .kkt_sweep_masked_into(loss, design, rows, y, eta, lambda, c, resid)
    }

    /// Batched look-ahead KKT sweep; `None` when the backend has no
    /// batched kernel for (loss, shape).
    pub fn kkt_sweep_batch(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        self.backend
            .kkt_sweep_batch(loss, design, y, eta, lambdas, l1_norm)
    }

    /// Buffer-reusing batched look-ahead sweep (see
    /// [`Backend::kkt_sweep_batch_into`]).
    #[allow(clippy::too_many_arguments)]
    pub fn kkt_sweep_batch_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
        batch: &mut KktBatch,
    ) -> Result<bool> {
        self.backend
            .kkt_sweep_batch_into(loss, design, y, eta, lambdas, l1_norm, batch)
    }

    /// Weighted Gram panel (Algorithm-1 augmentation); `w = None`
    /// means unit weights.
    pub fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        self.backend.gram_block(xe_t, w, xd_t, e, d, n)
    }

    /// Buffer-reusing Gram panel (see [`Backend::gram_block_into`]).
    #[allow(clippy::too_many_arguments)]
    pub fn gram_block_into(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
        out: &mut Vec<f64>,
    ) -> Result<bool> {
        self.backend.gram_block_into(xe_t, w, xd_t, e, d, n, out)
    }
}

/// An engine bound to one registered design: what the path driver uses
/// for its full KKT sweeps ([`crate::path::PathFitter::fit_with_engine`]).
///
/// The registered design sits behind an `Arc` so fold-restricted
/// clones ([`Self::fold`]) share one upload: a 10-fold CV registers
/// the design once and every fold sweep runs against the same device
/// panels through the masked kernel.
pub struct EngineSweep<'a> {
    pub engine: &'a RuntimeEngine,
    pub design: std::sync::Arc<RegisteredDesign>,
    pub loss: Loss,
    /// Borderline band re-verified in f64 (fraction of λ). Irrelevant
    /// for exact-f64 backends, load-bearing for f32 artifact backends.
    pub recheck_band: f64,
    /// Look-ahead batch width B: one batched sweep serves the KKT
    /// checks of the next B λ steps (Larsson 2021). 0 disables
    /// batching (per-λ sequential sweeps only).
    pub lookahead: usize,
    /// Row restriction for fold sweeps: global row indices into the
    /// registered design, `None` = all rows. When set, full sweeps
    /// route through [`Backend::kkt_sweep_masked_into`] and look-ahead
    /// batching is off (see [`Self::fold`]).
    pub rows: Option<Vec<usize>>,
}

impl<'a> EngineSweep<'a> {
    /// Bind `engine` to a dense design; returns None when the engine
    /// has no sweep kernel for this (loss, n, p).
    pub fn new(
        engine: &'a RuntimeEngine,
        design: &crate::linalg::DenseMatrix,
        loss: Loss,
    ) -> Result<Option<Self>> {
        let (n, p) = (design.nrows(), design.ncols());
        if !engine.supports_sweep(loss, n, p) {
            return Ok(None);
        }
        let reg = engine.register_design(design.data(), n, p)?;
        Ok(Some(Self {
            engine,
            design: std::sync::Arc::new(reg),
            loss,
            recheck_band: 1e-3,
            lookahead: 4,
            rows: None,
        }))
    }

    /// Bind `engine` to a design pulled from a [`ColumnSource`] — the
    /// out-of-core path (`hx fit --design file.hxd`). Same None
    /// semantics as [`EngineSweep::new`].
    pub fn from_source(
        engine: &'a RuntimeEngine,
        source: Box<dyn ColumnSource>,
        loss: Loss,
    ) -> Result<Option<Self>> {
        let (n, p) = (source.n(), source.p());
        if !engine.supports_sweep(loss, n, p) {
            return Ok(None);
        }
        let reg = engine.register_source(source)?;
        Ok(Some(Self {
            engine,
            design: std::sync::Arc::new(reg),
            loss,
            recheck_band: 1e-3,
            lookahead: 4,
            rows: None,
        }))
    }

    /// Set the look-ahead batch width (0 = per-λ sequential sweeps).
    pub fn with_lookahead(mut self, lookahead: usize) -> Self {
        self.lookahead = lookahead;
        self
    }

    /// Restrict this binding to a row subset (a CV training fold).
    /// Shares the registered design (`Arc` clone — no re-upload); full
    /// sweeps route through the backend's masked kernel over `rows`.
    /// Look-ahead is disabled: its Gap-Safe masks alter screened sets
    /// and hence coordinate-descent visit order, which would break the
    /// CV determinism contract (engine-routed fold fits bit-identical
    /// to host-path fold fits).
    pub fn fold(&self, rows: Vec<usize>) -> EngineSweep<'a> {
        EngineSweep {
            engine: self.engine,
            design: std::sync::Arc::clone(&self.design),
            loss: self.loss,
            recheck_band: self.recheck_band,
            lookahead: 0,
            rows: Some(rows),
        }
    }

    /// A clone of this binding with look-ahead disabled. The CV full
    /// refit uses this so the engine-routed and host-path refits see
    /// identical screened sets (same rationale as [`Self::fold`]).
    pub fn without_lookahead(&self) -> EngineSweep<'a> {
        EngineSweep {
            engine: self.engine,
            design: std::sync::Arc::clone(&self.design),
            loss: self.loss,
            recheck_band: self.recheck_band,
            lookahead: 0,
            rows: self.rows.clone(),
        }
    }

    /// Full correlation sweep through the backend, with native f64
    /// re-verification of the borderline band around λ. Returns false
    /// (leaving `c` untouched) when the backend path is unavailable,
    /// in which case the caller falls back to the native sweep.
    pub fn full_sweep<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        lambda: f64,
        c: &mut [f64],
    ) -> bool {
        let mut scratch = SweepScratch::default();
        self.full_sweep_into(native, y, eta, resid, lambda, c, &mut scratch)
    }

    /// Allocation-reusing twin of [`Self::full_sweep`]: the backend
    /// writes into `scratch` (grown once, reused every step), so the
    /// steady-state path loop performs no per-sweep allocation with an
    /// in-place backend.
    #[allow(clippy::too_many_arguments)]
    pub fn full_sweep_into<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        lambda: f64,
        c: &mut [f64],
        scratch: &mut SweepScratch,
    ) -> bool {
        let served = match &self.rows {
            // Fold binding: y/eta/resid are compact (fold-length) and
            // the backend gathers kept rows itself.
            Some(rows) => self.engine.kkt_sweep_masked_into(
                self.loss,
                &self.design,
                rows,
                y,
                eta,
                lambda,
                &mut scratch.c,
                &mut scratch.resid,
            ),
            None => self.engine.kkt_sweep_into(
                self.loss,
                &self.design,
                y,
                eta,
                lambda,
                &mut scratch.c,
                &mut scratch.resid,
            ),
        };
        match served {
            Ok(true) => {
                debug_assert_eq!(scratch.c.len(), c.len());
                if self.engine.is_exact() {
                    // Exact f64 backend: nothing to re-verify.
                    c.copy_from_slice(&scratch.c);
                    return true;
                }
                let lo = lambda * (1.0 - self.recheck_band);
                let hi = lambda * (1.0 + self.recheck_band);
                for (j, cv) in scratch.c.iter().enumerate() {
                    let a = cv.abs();
                    c[j] = if a >= lo && a <= hi {
                        // Reduced precision can't be trusted at the
                        // threshold: recompute in f64.
                        native.col_dot(j, resid)
                    } else {
                        *cv
                    };
                }
                true
            }
            _ => false,
        }
    }

    /// Batched look-ahead sweep (Larsson 2021): one correlation sweep
    /// at the current iterate yields Gap-Safe keep-masks for several
    /// upcoming λ values. On success `c` is refreshed with the
    /// f64-verified correlation vector and the per-λ masks are
    /// returned; `None` means the backend has no batched kernel and
    /// the caller falls back to per-λ sweeps.
    ///
    /// Precision contract: for reduced-precision backends every entry
    /// within `recheck_band` of *any* requested λ is recomputed in f64,
    /// and the masks are rebuilt from the corrected correlations with
    /// an extra `recheck_band` of slack on the sphere threshold — the
    /// sphere test's per-column cutoff sits *below* the λ band, so
    /// uncorrected entries (trusted to within `recheck_band·λ`, the
    /// same trust model as [`Self::full_sweep`]) can only be
    /// conservatively kept, never wrongly discarded.
    #[allow(clippy::too_many_arguments)]
    pub fn look_ahead<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        l1_norm: f64,
        lambdas: &[f64],
        c: &mut [f64],
    ) -> Option<Vec<Vec<bool>>> {
        let mut scratch = SweepScratch::default();
        let mut masks = Vec::new();
        if self.look_ahead_into(
            native, y, eta, resid, l1_norm, lambdas, c, &mut masks, &mut scratch,
        ) {
            Some(masks)
        } else {
            None
        }
    }

    /// Allocation-reusing twin of [`Self::look_ahead`]: the batched
    /// sweep lands in `scratch.batch` and the per-λ keep masks are
    /// recycled into `masks` (their capacity survives across steps).
    /// Returns `true` when the backend produced a usable batch.
    #[allow(clippy::too_many_arguments)]
    pub fn look_ahead_into<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        l1_norm: f64,
        lambdas: &[f64],
        c: &mut [f64],
        masks: &mut Vec<Vec<bool>>,
        scratch: &mut SweepScratch,
    ) -> bool {
        // Fold bindings never batch: `fold()` zeroes `lookahead`, and
        // the `rows` guard keeps a hand-built masked binding from
        // reaching the unmasked batch kernel.
        if self.lookahead == 0 || self.rows.is_some() || lambdas.is_empty() {
            return false;
        }
        match self.engine.kkt_sweep_batch_into(
            self.loss,
            &self.design,
            y,
            eta,
            lambdas,
            l1_norm,
            &mut scratch.batch,
        ) {
            Ok(true) => {}
            _ => return false,
        }
        let batch = &mut scratch.batch;
        debug_assert_eq!(batch.c.len(), c.len());
        if self.engine.is_exact() {
            c.copy_from_slice(&batch.c);
            // Hand the backend-built masks to the caller and keep the
            // caller's old masks (and their capacity) as next step's
            // batch scratch.
            std::mem::swap(masks, &mut batch.keep);
            return true;
        }
        let lo_l = lambdas.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi_l = lambdas.iter().cloned().fold(0.0f64, f64::max);
        let (lo, hi) = (
            lo_l * (1.0 - self.recheck_band),
            hi_l * (1.0 + self.recheck_band),
        );
        for (j, cv) in batch.c.iter().enumerate() {
            let a = cv.abs();
            c[j] = if a >= lo && a <= hi {
                native.col_dot(j, resid)
            } else {
                *cv
            };
        }
        let xt_inf = crate::linalg::blas::amax(c);
        masks.truncate(lambdas.len());
        masks.resize_with(lambdas.len(), Vec::new);
        for (keep, &l) in masks.iter_mut().zip(lambdas.iter()) {
            let gap = self.loss.duality_gap(y, eta, resid, xt_inf, l, l1_norm);
            crate::screening::lookahead_keep_into(
                c,
                &self.design.col_norms,
                xt_inf,
                gap,
                l,
                self.recheck_band,
                keep,
            );
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DesignMatrix, SyntheticSpec};

    fn dense_problem(n: usize, p: usize) -> (crate::linalg::DenseMatrix, Vec<f64>) {
        let data = SyntheticSpec::new(n, p, 3).rho(0.2).seed(11).generate();
        let dense = match data.design {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!(),
        };
        (dense, data.response)
    }

    #[test]
    fn native_engine_reports_backend() {
        let e = RuntimeEngine::native();
        assert_eq!(e.backend_name(), "native");
        assert!(e.num_ops() > 0);
    }

    #[test]
    fn native_correlation_matches_direct() {
        let (dense, y) = dense_problem(30, 12);
        let e = RuntimeEngine::native();
        let reg = e.register_design(dense.data(), 30, 12).unwrap();
        let c = e.correlation(&reg, &y).unwrap().expect("native kernel");
        for j in 0..12 {
            assert!((c[j] - dense.col_dot(j, &y)).abs() < 1e-12, "col {j}");
        }
    }

    #[test]
    fn native_supports_all_shapes_except_poisson() {
        let e = RuntimeEngine::native();
        assert!(e.supports_sweep(Loss::Gaussian, 123, 456));
        assert!(e.supports_sweep(Loss::Logistic, 7, 9));
        assert!(!e.supports_sweep(Loss::Poisson, 200, 2_000));
    }

    #[test]
    fn engine_sweep_binds_and_sweeps() {
        let (dense, y) = dense_problem(40, 15);
        let e = RuntimeEngine::native();
        let sweep = EngineSweep::new(&e, &dense, Loss::Gaussian)
            .unwrap()
            .expect("native always binds");
        let eta = vec![0.0; 40];
        let resid = y.clone();
        let mut c = vec![0.0; 15];
        assert!(sweep.full_sweep(&dense, &y, &eta, &resid, 0.5, &mut c));
        for j in 0..15 {
            assert!((c[j] - dense.col_dot(j, &y)).abs() < 1e-12);
        }
    }

    #[test]
    fn fold_binding_masked_sweep_matches_fold_view_bitwise() {
        let (dense, y) = dense_problem(31, 9);
        let e = RuntimeEngine::native_threaded(2);
        let sweep = EngineSweep::new(&e, &dense, Loss::Gaussian)
            .unwrap()
            .expect("native always binds");
        let rows: Vec<usize> = (0..31).filter(|i| i % 3 != 0).collect();
        let fold = sweep.fold(rows.clone());
        assert_eq!(fold.lookahead, 0, "fold bindings must not batch");
        let view = crate::cv::FoldView::from_rows(&dense, rows.clone());
        let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
        let eta = vec![0.0; rows.len()];
        let resid = yf.clone(); // Gaussian pseudo-residual at η = 0
        let mut c = vec![0.0; 9];
        assert!(fold.full_sweep(&view, &yf, &eta, &resid, 0.5, &mut c));
        for j in 0..9 {
            assert_eq!(
                c[j].to_bits(),
                view.col_dot(j, &resid).to_bits(),
                "masked engine sweep differs from host fold view at col {j}"
            );
        }
    }

    #[test]
    fn fold_binding_never_serves_look_ahead() {
        let (dense, y) = dense_problem(20, 6);
        let e = RuntimeEngine::native();
        let sweep = EngineSweep::new(&e, &dense, Loss::Gaussian)
            .unwrap()
            .expect("native always binds");
        let rows: Vec<usize> = (0..15).collect();
        let mut fold = sweep.fold(rows.clone());
        fold.lookahead = 4; // even forced back on, `rows` blocks batching
        let view = crate::cv::FoldView::from_rows(&dense, rows.clone());
        let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
        let eta = vec![0.0; rows.len()];
        let resid = yf.clone();
        let mut c = vec![0.0; 6];
        assert!(fold
            .look_ahead(&view, &yf, &eta, &resid, 0.0, &[0.5, 0.4], &mut c)
            .is_none());
    }

    #[test]
    fn poisson_binding_is_none() {
        let (dense, _) = dense_problem(20, 8);
        let e = RuntimeEngine::native();
        assert!(EngineSweep::new(&e, &dense, Loss::Poisson).unwrap().is_none());
    }

    #[test]
    fn manifest_missing_is_error() {
        // Without `pjrt`: feature-gate error. With `pjrt`: manifest
        // read failure. Either way, a clean Err — never a panic.
        let err = RuntimeEngine::load_dir(Path::new("/nonexistent-dir-xyz"));
        assert!(err.is_err());
    }
}
