//! Standardization (paper §4, first paragraph).
//!
//! * Dense designs: each predictor is centered with its mean and scaled
//!   by the *uncorrected* sample standard deviation (divide by n).
//! * Sparse designs: scaled only — centering would destroy sparsity.
//!   This is the standard sparse-GLM compromise (glmnet does the same
//!   with `standardize = TRUE` on sparse input).
//! * The response is centered (with the mean) for the Gaussian loss
//!   only, matching the paper exactly.
//!
//! Constant (zero-variance) columns are left unscaled (their scale is
//! reported as 1) and can never enter the model because their
//! correlation is 0 after centering.

use super::DesignMatrix;
use crate::linalg::Design;
use crate::loss::Loss;

/// Record of the applied transformation, so predictions can be mapped
/// back to the original scale.
#[derive(Clone, Debug)]
pub struct Standardization {
    pub col_means: Vec<f64>,
    pub col_scales: Vec<f64>,
    pub y_mean: f64,
}

impl Standardization {
    /// Map coefficients for standardized X back to the original scale.
    pub fn unstandardize_coefs(&self, beta: &[f64]) -> (Vec<f64>, f64) {
        let mut raw = vec![0.0; beta.len()];
        let mut intercept = self.y_mean;
        for j in 0..beta.len() {
            raw[j] = beta[j] / self.col_scales[j];
            intercept -= raw[j] * self.col_means[j];
        }
        (raw, intercept)
    }
}

/// Standardize a design + response in place; returns the transformation.
pub fn standardize(x: &mut DesignMatrix, y: &mut [f64], loss: Loss) -> Standardization {
    let n = match x {
        DesignMatrix::Dense(m) => m.nrows(),
        DesignMatrix::Sparse(m) => m.nrows(),
    };
    let nf = n as f64;
    let (means, scales) = match x {
        DesignMatrix::Dense(m) => {
            let p = m.ncols();
            let mut means = vec![0.0; p];
            let mut scales = vec![1.0; p];
            for j in 0..p {
                let col = m.col_mut(j);
                let mean = col.iter().sum::<f64>() / nf;
                let mut ss = 0.0;
                for v in col.iter_mut() {
                    *v -= mean;
                    ss += *v * *v;
                }
                let sd = (ss / nf).sqrt();
                let scale = if sd > 0.0 { sd } else { 1.0 };
                if scale != 1.0 {
                    for v in col.iter_mut() {
                        *v /= scale;
                    }
                }
                means[j] = mean;
                scales[j] = scale;
            }
            (means, scales)
        }
        DesignMatrix::Sparse(m) => {
            let p = m.ncols();
            let mut means = vec![0.0; p]; // not centered
            let mut scales = vec![1.0; p];
            for j in 0..p {
                let mean = m.col_mean(j);
                // Uncorrected sd around the (uncentered!) mean:
                // Var = E[x²] − mean², where E over all n rows.
                let (_, vals) = m.col(j);
                let sumsq: f64 = vals.iter().map(|v| v * v).sum();
                let var = (sumsq / nf - mean * mean).max(0.0);
                let sd = var.sqrt();
                let scale = if sd > 0.0 { sd } else { 1.0 };
                if scale != 1.0 {
                    m.scale_col(j, 1.0 / scale);
                }
                means[j] = 0.0;
                scales[j] = scale;
            }
            (means, scales)
        }
    };
    let y_mean = if matches!(loss, Loss::Gaussian) {
        let mu = y.iter().sum::<f64>() / nf;
        for v in y.iter_mut() {
            *v -= mu;
        }
        mu
    } else {
        0.0
    };
    Standardization {
        col_means: means,
        col_scales: scales,
        y_mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, DenseMatrix, Design};

    #[test]
    fn dense_columns_zero_mean_unit_sd() {
        let m = DenseMatrix::from_rows(&[
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 60.0],
        ]);
        let mut x = DesignMatrix::Dense(m);
        let mut y = vec![1.0, 2.0, 6.0];
        let st = standardize(&mut x, &mut y, Loss::Gaussian);
        if let DesignMatrix::Dense(m) = &x {
            for j in 0..2 {
                let col = m.col(j);
                let mean: f64 = col.iter().sum::<f64>() / 3.0;
                let ss: f64 = col.iter().map(|v| v * v).sum::<f64>() / 3.0;
                assert!(mean.abs() < 1e-12, "mean {mean}");
                assert!((ss - 1.0).abs() < 1e-12, "var {ss}");
            }
        }
        // y centered for Gaussian.
        assert!((y.iter().sum::<f64>()).abs() < 1e-12);
        assert!((st.y_mean - 3.0).abs() < 1e-12);
        assert!((st.col_means[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn logistic_response_not_centered() {
        let m = DenseMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let mut x = DesignMatrix::Dense(m);
        let mut y = vec![0.0, 1.0, 1.0];
        let st = standardize(&mut x, &mut y, Loss::Logistic);
        assert_eq!(y, vec![0.0, 1.0, 1.0]);
        assert_eq!(st.y_mean, 0.0);
    }

    #[test]
    fn constant_column_survives() {
        let m = DenseMatrix::from_rows(&[vec![5.0], vec![5.0], vec![5.0]]);
        let mut x = DesignMatrix::Dense(m);
        let mut y = vec![0.0; 3];
        let st = standardize(&mut x, &mut y, Loss::Gaussian);
        assert_eq!(st.col_scales[0], 1.0);
        if let DesignMatrix::Dense(m) = &x {
            assert_eq!(m.col(0), &[0.0, 0.0, 0.0]);
        }
    }

    #[test]
    fn sparse_scaled_not_centered() {
        let sp = CscMatrix::from_triplets(4, 1, &[(0, 0, 2.0), (2, 0, 4.0)]);
        let mut x = DesignMatrix::Sparse(sp);
        let mut y = vec![1.0; 4];
        standardize(&mut x, &mut y, Loss::Logistic);
        if let DesignMatrix::Sparse(m) = &x {
            // mean of [2,0,4,0] = 1.5, E[x²] = 5, var = 2.75
            let sd = 2.75f64.sqrt();
            let (_, vals) = m.col(0);
            assert!((vals[0] - 2.0 / sd).abs() < 1e-12);
            assert!((vals[1] - 4.0 / sd).abs() < 1e-12);
            assert_eq!(m.nnz(), 2, "sparsity preserved");
        }
    }

    #[test]
    fn unstandardize_roundtrip() {
        // yhat = Xs·βs + 0 must equal Xraw·βraw + intercept.
        let rows = vec![vec![1.0, -1.0], vec![2.0, 0.5], vec![4.0, 3.0], vec![0.0, 1.5]];
        let m = DenseMatrix::from_rows(&rows);
        let mut x = DesignMatrix::Dense(m.clone());
        let mut y = vec![1.0, 2.0, 3.0, 4.0];
        let st = standardize(&mut x, &mut y, Loss::Gaussian);
        let beta_s = vec![0.7, -1.2];
        let (beta_raw, b0) = st.unstandardize_coefs(&beta_s);
        for i in 0..4 {
            let mut pred_s = 0.0;
            for j in 0..2 {
                pred_s += match &x {
                    DesignMatrix::Dense(ms) => ms.at(i, j) * beta_s[j],
                    _ => unreachable!(),
                };
            }
            // prediction on the original y scale
            let pred_s = pred_s + st.y_mean;
            let mut pred_raw = b0;
            for j in 0..2 {
                pred_raw += rows[i][j] * beta_raw[j];
            }
            assert!((pred_s - pred_raw).abs() < 1e-10, "row {i}");
        }
        let _ = x.density();
    }
}
