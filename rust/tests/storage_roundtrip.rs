//! Integration: the out-of-core `.hxd` path — pack → stream → fit —
//! is bit-identical to the resident path, stays inside the two-panel
//! memory bound, and fails loudly (never hangs, never panics) on
//! corrupt or truncated files.
//!
//! `HX_TEST_SHAPE=small` shrinks the shapes for miri/sanitizer runs;
//! both presets keep p ragged for the shard counts under test and keep
//! p not a multiple of the block widths, so the packed layout always
//! exercises a ragged tail block.

mod common;

use std::path::PathBuf;

use common::test_shape;
use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::{blas, DenseMatrix};
use hessian_screening::loss::Loss;
use hessian_screening::path::{PathFitter, PathSettings};
use hessian_screening::runtime::{EngineSweep, RuntimeEngine, ShardedDesignView};
use hessian_screening::screening::ScreeningKind;
use hessian_screening::storage::{pack_dense, ColumnSource, HxdSource, DEFAULT_BLOCK_COLS};

fn dense_of(data: &hessian_screening::data::Dataset) -> &DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hxd-it-{}-{tag}.hxd", std::process::id()))
}

/// Property-style roundtrip: for ragged p and odd block widths, every
/// column read back from disk is bit-identical to the packed design,
/// and the manifest norms are bit-identical to a blas recompute.
#[test]
fn pack_then_read_is_bitwise_across_block_widths() {
    let (n, p) = test_shape((40, 157), (12, 37));
    let data = SyntheticSpec::new(n, p, p.min(6)).rho(0.3).seed(61).generate();
    let dense = dense_of(&data);
    for bc in [1usize, 3, DEFAULT_BLOCK_COLS, p, p + 5] {
        let path = tmp(&format!("rt-{bc}"));
        let summary = pack_dense(&path, dense, bc, Loss::Gaussian, None).expect("pack");
        assert_eq!((summary.n, summary.p), (n, p));
        let mut src = HxdSource::open(&path).expect("open");
        assert_eq!((src.n(), src.p()), (n, p));
        assert!(src.response().is_none());
        // Read in deliberately odd ranges that straddle block edges.
        let mut c0 = 0usize;
        let widths = [1usize, bc.max(2) - 1, bc, bc + 2, 7];
        let mut w = 0usize;
        while c0 < p {
            let c1 = (c0 + widths[w % widths.len()]).min(p);
            let panel = src.read_cols(c0, c1).expect("read");
            for (k, j) in (c0..c1).enumerate() {
                let got = &panel[k * n..(k + 1) * n];
                let want = dense.col(j);
                assert!(
                    got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "bc={bc}: column {j} changed bits through the file"
                );
            }
            c0 = c1;
            w += 1;
        }
        for (j, &norm) in src.col_norms().iter().enumerate() {
            assert_eq!(
                norm.to_bits(),
                blas::nrm2(dense.col(j)).to_bits(),
                "bc={bc}: manifest norm {j}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// THE acceptance bar for this subsystem: `hx fit --design file.hxd`
/// semantics (stream from disk through the sharded pipeline, fit over
/// the host-side view) produce bit-identical paths to the resident
/// fit of the same data — coefficients, λ grids, deviance ratios,
/// active-set sizes, and per-step screening counts — across
/// shards ∈ {1, 4} × threads ∈ {1, 4}, Gaussian and logistic.
#[test]
fn hxd_fit_is_bit_identical_to_resident_fit() {
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let (n, p) = test_shape((60, 402), (16, 46));
        let data = SyntheticSpec::new(n, p, 6)
            .rho(0.3)
            .loss(loss)
            .seed(71)
            .generate();
        let dense = dense_of(&data);
        let path = tmp(&format!("fit-{loss:?}"));
        // A block width that divides neither p nor the shard chunks.
        pack_dense(&path, dense, 19, loss, Some(&data.response)).expect("pack");
        let mut settings = PathSettings::default();
        settings.path_length = 25;
        let fitter = PathFitter::new(loss, ScreeningKind::Hessian).with_settings(settings);
        for shards in [1usize, 4] {
            for threads in [1usize, 4] {
                let tag = format!("{loss:?} shards={shards} threads={threads}");
                // Resident reference fit.
                let engine_a = RuntimeEngine::native_sharded(shards, threads);
                let sweep_a = EngineSweep::new(&engine_a, dense, loss).unwrap().unwrap();
                let a = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep_a));
                // Streamed fit: design and response both from the file.
                let mut src = HxdSource::open(&path).expect("open");
                assert_eq!(src.loss(), loss, "{tag}: loss tag survives the file");
                let y = src.take_response().expect("packed response");
                assert_eq!(y, data.response, "{tag}: response survives the file");
                let engine_b = RuntimeEngine::native_sharded(shards, threads);
                let sweep_b = EngineSweep::from_source(&engine_b, Box::new(src), loss)
                    .unwrap()
                    .unwrap();
                let view = ShardedDesignView::new(&sweep_b.design).expect("host view");
                let b = fitter.fit_with_engine(&view, &y, Some(&sweep_b));
                assert_eq!(a.lambdas, b.lambdas, "{tag}: λ grid");
                assert_eq!(a.betas, b.betas, "{tag}: coefficients");
                assert_eq!(a.dev_ratios, b.dev_ratios, "{tag}: deviance ratios");
                assert_eq!(a.converged, b.converged, "{tag}: convergence");
                assert_eq!(a.steps.len(), b.steps.len(), "{tag}: step count");
                for (sa, sb) in a.steps.iter().zip(&b.steps) {
                    assert_eq!(sa.active, sb.active, "{tag}: active-set size");
                    assert_eq!(sa.screened, sb.screened, "{tag}: screened count");
                    assert_eq!(sa.passes, sb.passes, "{tag}: CD passes");
                }
            }
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// The memory bound the subsystem exists for: streaming registration
/// reads each design byte once and never holds more than two shard
/// panels (and in particular never a full n×p buffer).
#[test]
fn streamed_registration_stays_within_two_panels() {
    let (n, p) = test_shape((50, 322), (14, 46));
    let data = SyntheticSpec::new(n, p, 5).seed(83).generate();
    let dense = dense_of(&data);
    let path = tmp("mem");
    pack_dense(&path, dense, 11, Loss::Gaussian, None).expect("pack");
    for shards in [2usize, 5] {
        let src = HxdSource::open(&path).expect("open");
        let engine = RuntimeEngine::native_sharded(shards, 1);
        let reg = engine.register_source(Box::new(src)).expect("register");
        let _ = engine.correlation(&reg, &data.response).unwrap().unwrap();
        let u = engine.upload_stats().expect("stats");
        let chunk = (p + shards - 1) / shards;
        assert_eq!(u.staged, shards);
        assert_eq!(u.uploaded, shards);
        assert_eq!(u.bytes_read, (8 * n * p) as u64, "{shards} shards: one pass");
        assert_eq!(u.inflight_bytes, 0, "{shards} shards: drained");
        assert_eq!(u.max_panel_bytes, (8 * n * chunk) as u64);
        assert!(
            u.max_panel_bytes < (8 * n * p) as u64,
            "{shards} shards: a full-design panel was staged"
        );
        assert!(
            u.peak_inflight_bytes <= 2 * u.max_panel_bytes,
            "{shards} shards: peak {} exceeds two panels of {}",
            u.peak_inflight_bytes,
            u.max_panel_bytes
        );
    }
    let _ = std::fs::remove_file(&path);
}

fn flip_byte(path: &PathBuf, offset: usize) {
    let mut bytes = std::fs::read(path).expect("read file");
    bytes[offset] ^= 0xff;
    std::fs::write(path, bytes).expect("write file");
}

/// Corruption in a block that only a *later* shard touches must fail
/// the fit with a descriptive error from the stager thread — not a
/// panic, not a hang — while a corrupted first shard fails
/// registration itself, and truncation fails at open.
#[test]
fn corrupt_or_truncated_files_fail_loudly_on_every_surface() {
    let (n, p) = test_shape((30, 97), (10, 29));
    let data = SyntheticSpec::new(n, p, 4).seed(89).generate();
    let dense = dense_of(&data);
    let path = tmp("corrupt");
    pack_dense(&path, dense, 5, Loss::Gaussian, Some(&data.response)).expect("pack");

    // Flip a data byte in the very last column: with 4 shards only the
    // final shard's staging read (in the stager thread) sees it.
    flip_byte(&path, 48 + (p - 1) * n * 8 + 3);
    let mut src = HxdSource::open(&path).expect("open still succeeds: manifest is intact");
    let y = src.take_response().expect("response");
    let engine = RuntimeEngine::native_sharded(4, 1);
    let reg = engine
        .register_source(Box::new(src))
        .expect("shard 0 is clean, registration returns");
    let err = match engine.correlation(&reg, &y) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("correlation over a corrupt shard must fail"),
    };
    assert!(
        err.contains("checksum mismatch") && err.contains("corrupt"),
        "undiagnostic error: {err}"
    );

    // Same corruption in column 0: the synchronous first-shard read
    // surfaces the error from register_source itself.
    pack_dense(&path, dense, 5, Loss::Gaussian, None).expect("repack");
    flip_byte(&path, 48 + 2);
    let src = HxdSource::open(&path).expect("open");
    let err = match engine.register_source(Box::new(src)) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("registering a corrupt first shard must fail"),
    };
    assert!(err.contains("checksum mismatch"), "undiagnostic error: {err}");

    // Truncation is caught at open, before any column is trusted.
    pack_dense(&path, dense, 5, Loss::Gaussian, None).expect("repack");
    let bytes = std::fs::read(&path).expect("read");
    std::fs::write(&path, &bytes[..bytes.len() - 8]).expect("truncate");
    let err = match HxdSource::open(&path) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("opening a truncated file must fail"),
    };
    assert!(err.contains("truncated"), "undiagnostic error: {err}");
    let _ = std::fs::remove_file(&path);
}
