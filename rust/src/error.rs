//! Minimal error substrate.
//!
//! The offline image has no crate registry, so the crate is
//! zero-dependency; this module provides the 5% of `anyhow` the
//! runtime layer needs: a string-carrying [`Error`], a defaulted
//! [`Result`] alias, a [`Context`] extension trait, and the [`err!`]
//! format macro.

use std::fmt;

/// A boxed, message-carrying error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Self { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self { msg: e.to_string() }
    }
}

/// Crate-wide result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style message chaining.
pub trait Context<T> {
    /// Attach a fixed message, keeping the original error as a suffix.
    fn context(self, msg: impl Into<String>) -> Result<T>;

    /// Attach a lazily built message.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Build an [`Error`] from a format string, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_macro() {
        let e = crate::err!("op {} failed", 3);
        assert_eq!(e.to_string(), "op 3 failed");
        let e2: Error = "plain".into();
        assert_eq!(e2.to_string(), "plain");
    }

    #[test]
    fn context_chains_messages() {
        let base: std::result::Result<(), Error> = Err(Error::msg("inner"));
        let wrapped = base.context("outer");
        assert_eq!(wrapped.unwrap_err().to_string(), "outer: inner");
        let lazy: std::result::Result<(), Error> = Err(Error::msg("x"));
        let wrapped = lazy.with_context(|| "lazy ctx".to_string());
        assert_eq!(wrapped.unwrap_err().to_string(), "lazy ctx: x");
    }

    #[test]
    fn io_error_converts() {
        let io = std::fs::read_to_string("/nonexistent-file-xyz");
        let err: Result<String> = io.map_err(Error::from);
        assert!(err.is_err());
    }
}
