# Build/test entry points referenced throughout the docs and the
# integration tests (rust/tests/runtime_roundtrip.rs). The CI workflow
# (.github/workflows/ci.yml) calls these same targets, so a local
# `make ci` runs exactly what CI runs — no drift.
#
#   make artifacts       lower the L2 graphs to HLO text (needs jax)
#   make build           release build, default features (pure Rust)
#   make test            build artifacts when possible, then cargo test
#   make test-rust       crate tests only (the tier-1 gate)
#   make bench           run the experiment benches (quick presets)
#   make bench-compile   compile benches without running them
#   make bench-ci        quick sweep bench -> $(BENCH_JSON) (guarded:
#                        a failed bench publishes no JSON)
#   make bench-baseline  regenerate $(BENCH_BASELINE) from a real bench
#                        run (refuses on a dirty bench build / tree)
#   make perf-gate       diff $(BENCH_JSON) against $(BENCH_BASELINE)
#   make check-features  cargo check the feature powerset (pjrt,
#                        paranoid, none)
#   make check-oac       out-of-core acceptance: hx pack -> hx fit
#                        --design end-to-end, truncated file must fail
#   make check-cv        CV acceptance: the cv_equivalence suite plus
#                        hx cv --profile smoke runs (resident + .hxd)
#   make lint            the xtask invariant linter (blocking in CI)
#   make test-paranoid   crate tests with runtime invariant checks
#   make miri            miri over the concurrency subset (nightly)
#   make tsan            ThreadSanitizer over the threaded suites
#                        (nightly + rust-src)
#   make ci              mirror the CI workflow locally
#   make clean           remove build products

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR := artifacts
BENCH_JSON ?= BENCH_sweeps.json
BENCH_BASELINE ?= BENCH_baseline.json
# The CI bench configuration: quick shape, 2 threads, 2 shards — keep
# in sync with the records committed to $(BENCH_BASELINE).
BENCH_FLAGS ?= --quick --threads 2 --shards 2 --design
# Nightly toolchain for the dynamic-analysis targets. CI pins this via
# NIGHTLY_VERSION (.github/workflows/ci.yml); locally any installed
# nightly works: `make miri NIGHTLY=nightly-2026-07-15`.
NIGHTLY ?= nightly
TSAN_TARGET ?= x86_64-unknown-linux-gnu

.PHONY: all build test test-rust artifacts bench bench-compile bench-ci \
        bench-baseline perf-gate check-features check-oac check-cv lint \
        test-paranoid miri tsan ci fmt clippy clean

all: build

build:
	$(CARGO) build --release --workspace

# AOT artifacts for the PJRT backend. Requires a Python with jax
# installed; skipped gracefully by `make test` when unavailable.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

# Full test entry point: build artifacts when the Python toolchain is
# present (the PJRT tests skip politely otherwise), then run the crate
# tests.
test:
	-$(MAKE) artifacts
	$(CARGO) test -q

# Crate tests only — what tier-1 CI runs on a fresh checkout.
test-rust:
	$(CARGO) test -q

bench:
	$(CARGO) bench

bench-compile:
	$(CARGO) bench --no-run

# Quick sweep bench with a machine-readable record. Written to a temp
# file first: a bench that exits nonzero (e.g. malformed flags) must
# never publish a partial or stale $(BENCH_JSON).
bench-ci:
	rm -f $(BENCH_JSON) $(BENCH_JSON).tmp
	$(CARGO) bench --bench micro_kernels -- $(BENCH_FLAGS) --json $(BENCH_JSON).tmp \
	    || { echo "bench failed; $(BENCH_JSON) not produced" >&2; \
	         rm -f $(BENCH_JSON).tmp; exit 1; }
	mv $(BENCH_JSON).tmp $(BENCH_JSON)

# Regenerate the committed baseline from a real bench run on this
# machine. Guard rails: refuses when the bench sources are dirty in
# git (a baseline must be attributable to a commit), and goes through
# a temp file so a failed bench never clobbers the old baseline.
# Follow-up: eyeball the diff, then commit $(BENCH_BASELINE).
bench-baseline:
	@if ! git diff --quiet HEAD -- benches rust Cargo.toml Cargo.lock \
	    2>/dev/null; then \
	    echo "bench-baseline: bench sources are dirty in git; commit or" \
	         "stash first so the baseline is attributable" >&2; \
	    exit 1; \
	fi
	rm -f $(BENCH_BASELINE).tmp
	$(CARGO) bench --bench micro_kernels -- $(BENCH_FLAGS) \
	    --json $(BENCH_BASELINE).tmp \
	    || { echo "bench failed; $(BENCH_BASELINE) untouched" >&2; \
	         rm -f $(BENCH_BASELINE).tmp; exit 1; }
	mv $(BENCH_BASELINE).tmp $(BENCH_BASELINE)
	@echo "wrote $(BENCH_BASELINE); review the diff and commit it"

# Perf-trajectory gate: compare the fresh bench record against the
# committed baseline (warn > 1.25x, fail > 1.5x). Refresh ritual:
# `make bench-baseline` on a quiet machine (or download a trusted CI
# run's BENCH_sweeps artifact), then commit $(BENCH_BASELINE) — see
# README "Perf trajectory".
perf-gate:
	$(PYTHON) python/ci/bench_compare.py $(BENCH_JSON) $(BENCH_BASELINE)

# Feature powerset: the crate must at least type-check with every
# feature combination so cfg-gated code can't rot.
check-features:
	$(CARGO) check --workspace --no-default-features
	$(CARGO) check --workspace --features pjrt
	$(CARGO) check --workspace --no-default-features --features pjrt
	$(CARGO) check -p hessian-screening --features paranoid
	$(CARGO) check -p hessian-screening --features "paranoid pjrt"

# Out-of-core acceptance, end-to-end through the real binary: pack a
# synthetic design to .hxd, fit it streaming with a ragged shard split,
# then truncate the file and prove the fit fails loudly instead of
# reading garbage. Blocking in CI (job `oac`).
check-oac: build
	tmp=$$(mktemp -d) && trap 'rm -rf "$$tmp"' EXIT; \
	./target/release/hx pack --out "$$tmp/design.hxd" \
	    --n 120 --p 601 --s 8 --seed 7 --block-cols 37 && \
	./target/release/hx fit --design "$$tmp/design.hxd" \
	    --shards 3 --threads 2 --path-length 20 --profile && \
	truncate -s -8 "$$tmp/design.hxd" && \
	if ./target/release/hx fit --design "$$tmp/design.hxd" --shards 2 \
	    >/dev/null 2>&1; then \
	    echo "check-oac: FAIL — a truncated .hxd file must be rejected" >&2; \
	    exit 1; \
	else \
	    echo "check-oac: truncated file rejected as expected"; \
	fi

# Cross-validation acceptance, in two layers. First the equivalence
# suite (CV curves bit-identical across fold-worker counts, fold views
# vs. materialized subsets, engine-routed vs. host-path, .hxd vs.
# resident), then end-to-end smoke through the real binary: a resident
# `hx cv --profile` with an explicit thread split, and an out-of-core
# one over a packed .hxd with a ragged shard count. Blocking in CI
# (job `cv`).
check-cv: build
	$(CARGO) test -q --test cv_equivalence
	tmp=$$(mktemp -d) && trap 'rm -rf "$$tmp"' EXIT; \
	./target/release/hx cv --n 120 --p 300 --s 5 --folds 4 \
	    --path-length 15 --threads 2 --engine-threads 1 \
	    --folds-seed 7 --profile && \
	./target/release/hx pack --out "$$tmp/cv.hxd" \
	    --n 120 --p 301 --s 5 --seed 7 --block-cols 37 && \
	./target/release/hx cv --design "$$tmp/cv.hxd" --folds 4 --shards 3 \
	    --path-length 15 --threads 2 --profile

# Project-invariant linter (xtask/src/lint.rs): SAFETY comments on
# every unsafe block, no f32 in the f64-exact modules, no naked
# unwraps in library code, no raw thread::spawn outside the pipeline
# and the coordinator, no clocks in kernel inner loops. Blocking in CI.
lint:
	$(CARGO) run -q -p xtask -- lint

# Crate tests with the runtime invariant layer (src/invariants.rs)
# compiled in: Gram symmetry, screened-set soundness, shard reduction
# spot checks, upload counter balance.
test-paranoid:
	$(CARGO) test -q -p hessian-screening --features paranoid

# Miri over the curated concurrency subset: the shard upload pipeline,
# the coordinator pool, the upload-stats bookkeeping, and the storage
# layer (lib tests), plus — at HX_TEST_SHAPE=small — the full
# shard-equivalence and storage-roundtrip integration suites.
# -Zmiri-disable-isolation: shard.rs reads Instant::now for its stall
# bookkeeping and the storage tests touch the real filesystem, which
# isolation would reject.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" \
	    $(CARGO) +$(NIGHTLY) miri test -p hessian-screening --lib -- \
	    runtime::shard coordinator:: runtime::tests storage::
	HX_TEST_SHAPE=small MIRIFLAGS="-Zmiri-disable-isolation" \
	    $(CARGO) +$(NIGHTLY) miri test -p hessian-screening \
	    --test shard_equivalence
	HX_TEST_SHAPE=small MIRIFLAGS="-Zmiri-disable-isolation" \
	    $(CARGO) +$(NIGHTLY) miri test -p hessian-screening \
	    --test storage_roundtrip

# ThreadSanitizer over the threaded suites: lib concurrency tests plus
# the threads × shards equivalence matrix on shrunk shapes. Needs
# -Zbuild-std (instrumented std) and therefore rust-src + an explicit
# target triple.
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
	    $(CARGO) +$(NIGHTLY) test -Zbuild-std --target $(TSAN_TARGET) \
	    -p hessian-screening --lib -- runtime:: coordinator::
	HX_TEST_THREADS=4 HX_TEST_SHARDS=4 RUSTFLAGS="-Zsanitizer=thread" \
	    $(CARGO) +$(NIGHTLY) test -Zbuild-std --target $(TSAN_TARGET) \
	    -p hessian-screening --test shard_equivalence

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace -- -D warnings

# Mirror .github/workflows/ci.yml locally (same targets CI calls; the
# advisory miri/tsan jobs are opt-in because they need a nightly).
ci: fmt clippy lint build test-rust bench-compile check-features check-oac check-cv

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR) results
	rm -f $(BENCH_JSON) $(BENCH_JSON).tmp $(BENCH_BASELINE).tmp
	find python -name __pycache__ -type d -exec rm -rf {} +
