//! K-fold cross-validation for λ selection.
//!
//! The paper's opening motivation (§1): "the optimal λ is typically
//! unknown and must be estimated through model tuning, such as
//! cross-validation. This involves repeated refitting of the model to
//! new batches of data, which is computationally demanding" — which is
//! exactly why path-fitting speed (and hence screening) matters. This
//! module is that workload: k folds, each fitting a full path on a
//! *shared* λ grid (computed from the full data, glmnet-style), scored
//! on the held-out fold, aggregated into a CV curve with the usual
//! minimum-CV and one-standard-error selections. Folds run in parallel
//! on the [`crate::coordinator::Coordinator`].

use crate::coordinator::Coordinator;
use crate::data::DesignMatrix;
use crate::linalg::{CscMatrix, DenseMatrix, Design};
use crate::loss::Loss;
use crate::metrics::Summary;
use crate::path::{lambda_grid, PathFitter, PathSettings};
use crate::rng::Xoshiro256pp;
use crate::screening::ScreeningKind;

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvSettings {
    pub n_folds: usize,
    pub seed: u64,
    pub path: PathSettings,
    /// Parallelize across folds.
    pub threads: usize,
}

impl Default for CvSettings {
    fn default() -> Self {
        Self {
            n_folds: 10,
            seed: 0,
            path: PathSettings::default(),
            threads: Coordinator::auto().threads,
        }
    }
}

/// Result of a cross-validated path.
#[derive(Clone, Debug)]
pub struct CvFit {
    pub lambdas: Vec<f64>,
    /// Mean held-out deviance per λ (the CV curve).
    pub cv_mean: Vec<f64>,
    /// Standard error of the fold deviances per λ.
    pub cv_se: Vec<f64>,
    /// Index of the CV-minimizing λ.
    pub idx_min: usize,
    /// Largest λ within one SE of the minimum (the "1-SE rule").
    pub idx_1se: usize,
    /// Final path refit on the full data.
    pub full_fit: crate::path::PathFit,
}

impl CvFit {
    pub fn lambda_min(&self) -> f64 {
        self.lambdas[self.idx_min]
    }

    pub fn lambda_1se(&self) -> f64 {
        self.lambdas[self.idx_1se]
    }

    /// Coefficients at the CV-selected λ (sparse pairs).
    pub fn selected_coefs(&self, one_se: bool) -> &[(usize, f64)] {
        let idx = if one_se { self.idx_1se } else { self.idx_min };
        &self.full_fit.betas[idx.min(self.full_fit.betas.len() - 1)]
    }
}

/// Assign each observation to a fold (balanced, shuffled).
pub fn fold_assignments(n: usize, k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2, "need at least 2 folds");
    assert!(n >= k, "more folds than observations");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let mut fold = vec![0usize; n];
    for (pos, &i) in idx.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Extract the rows of a design (dense or sparse) where `keep[i]`.
fn subset_rows(design: &DesignMatrix, keep: &[bool]) -> DesignMatrix {
    let n_new = keep.iter().filter(|&&k| k).count();
    let mut row_map = vec![usize::MAX; design.nrows()];
    let mut r = 0;
    for i in 0..design.nrows() {
        if keep[i] {
            row_map[i] = r;
            r += 1;
        }
    }
    match design {
        DesignMatrix::Dense(m) => {
            let mut out = DenseMatrix::zeros(n_new, m.ncols());
            for j in 0..m.ncols() {
                let col = m.col(j);
                let ocol = out.col_mut(j);
                for i in 0..col.len() {
                    if keep[i] {
                        ocol[row_map[i]] = col[i];
                    }
                }
            }
            DesignMatrix::Dense(out)
        }
        DesignMatrix::Sparse(m) => {
            let mut triplets = Vec::new();
            for j in 0..m.ncols() {
                let (ri, vals) = m.col(j);
                for (&i, &v) in ri.iter().zip(vals) {
                    if keep[i as usize] {
                        triplets.push((row_map[i as usize], j, v));
                    }
                }
            }
            DesignMatrix::Sparse(CscMatrix::from_triplets(n_new, m.ncols(), &triplets))
        }
    }
}

/// Held-out deviance of a sparse coefficient vector.
fn holdout_deviance(
    design: &DesignMatrix,
    y: &[f64],
    holdout: &[usize],
    beta: &[(usize, f64)],
    loss: Loss,
) -> f64 {
    // η for the held-out rows only.
    let n = design.nrows();
    let mut eta_full = vec![0.0; n];
    for &(j, b) in beta {
        design.col_axpy(j, b, &mut eta_full);
    }
    let yh: Vec<f64> = holdout.iter().map(|&i| y[i]).collect();
    let eh: Vec<f64> = holdout.iter().map(|&i| eta_full[i]).collect();
    loss.deviance(&yh, &eh) / holdout.len().max(1) as f64
}

/// Run k-fold cross-validation. The λ grid is fixed from the *full*
/// data so fold curves are comparable (glmnet's convention).
pub fn cross_validate(
    design: &DesignMatrix,
    y: &[f64],
    loss: Loss,
    kind: ScreeningKind,
    settings: &CvSettings,
) -> CvFit {
    let n = design.nrows();
    let p = design.ncols();

    // Shared λ grid from the full data.
    let mut resid = vec![0.0; n];
    let eta0 = vec![0.0; n];
    loss.pseudo_residual_into(y, &eta0, &mut resid);
    let lambda_max = (0..p)
        .map(|j| design.col_dot(j, &resid).abs())
        .fold(0.0f64, f64::max);
    let ratio = settings
        .path
        .lambda_min_ratio
        .unwrap_or_else(|| crate::path::default_lambda_min_ratio(n, p));
    let lambdas = lambda_grid(lambda_max, ratio, settings.path.path_length);

    let folds = fold_assignments(n, settings.n_folds, settings.seed);
    let jobs: Vec<usize> = (0..settings.n_folds).collect();
    let coord = Coordinator::new(settings.threads);
    let fold_devs: Vec<Vec<f64>> = coord.run(jobs, |_, &f| {
        let keep: Vec<bool> = folds.iter().map(|&g| g != f).collect();
        let train_x = subset_rows(design, &keep);
        let train_y: Vec<f64> = (0..n).filter(|&i| keep[i]).map(|i| y[i]).collect();
        let holdout: Vec<usize> = (0..n).filter(|&i| !keep[i]).collect();
        let mut ps = settings.path.clone();
        ps.lambda_path = Some(lambdas.clone());
        // no early stopping inside folds: curves must align on the grid
        ps.dev_ratio_max = 1.0;
        ps.dev_change_min = 0.0;
        let fit = PathFitter::new(loss, kind)
            .with_settings(ps)
            .fit(&train_x, &train_y);
        (0..lambdas.len())
            .map(|k| {
                // Fall back to the last fitted step when the fold's path
                // ended early; an empty path means the null model.
                let beta: &[(usize, f64)] = fit
                    .betas
                    .get(k)
                    .or_else(|| fit.betas.last())
                    .map_or(&[], |b| b.as_slice());
                holdout_deviance(design, y, &holdout, beta, loss)
            })
            .collect()
    });

    let m = lambdas.len();
    let mut cv_mean = Vec::with_capacity(m);
    let mut cv_se = Vec::with_capacity(m);
    for k in 0..m {
        let vals: Vec<f64> = fold_devs.iter().map(|f| f[k]).collect();
        let s = Summary::of(&vals);
        cv_mean.push(s.mean);
        cv_se.push(s.sd / (vals.len() as f64).sqrt());
    }
    let idx_min = (0..m)
        .min_by(|&a, &b| cv_mean[a].total_cmp(&cv_mean[b]))
        .unwrap_or(0);
    // 1-SE rule: the largest λ (smallest index) whose CV mean is within
    // one SE of the minimum.
    let threshold = cv_mean[idx_min] + cv_se[idx_min];
    let idx_1se = (0..=idx_min)
        .find(|&k| cv_mean[k] <= threshold)
        .unwrap_or(idx_min);

    let mut ps = settings.path.clone();
    ps.lambda_path = Some(lambdas.clone());
    ps.dev_ratio_max = 1.0;
    ps.dev_change_min = 0.0;
    let full_fit = PathFitter::new(loss, kind).with_settings(ps).fit(design, y);

    CvFit {
        lambdas,
        cv_mean,
        cv_se,
        idx_min,
        idx_1se,
        full_fit,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn fold_assignments_balanced_and_deterministic() {
        let f = fold_assignments(103, 5, 7);
        assert_eq!(f.len(), 103);
        let mut counts = [0usize; 5];
        for &g in &f {
            counts[g] += 1;
        }
        for &c in &counts {
            assert!((20..=21).contains(&c), "unbalanced: {counts:?}");
        }
        assert_eq!(f, fold_assignments(103, 5, 7));
        assert_ne!(f, fold_assignments(103, 5, 8));
    }

    #[test]
    #[should_panic(expected = "at least 2 folds")]
    fn rejects_single_fold() {
        let _ = fold_assignments(10, 1, 0);
    }

    #[test]
    fn subset_rows_dense_and_sparse_agree() {
        let data = SyntheticSpec::new(20, 6, 2).density(0.4).seed(1).generate();
        let sparse = data.design.clone();
        let dense = match &sparse {
            DesignMatrix::Sparse(m) => DesignMatrix::Dense(m.to_dense()),
            _ => unreachable!(),
        };
        let keep: Vec<bool> = (0..20).map(|i| i % 3 != 0).collect();
        let sd = subset_rows(&dense, &keep);
        let ss = subset_rows(&sparse, &keep);
        assert_eq!(sd.nrows(), ss.nrows());
        let v: Vec<f64> = (0..sd.nrows()).map(|i| i as f64).collect();
        for j in 0..6 {
            assert!((sd.col_dot(j, &v) - ss.col_dot(j, &v)).abs() < 1e-12);
        }
    }

    #[test]
    fn cv_selects_reasonable_lambda_gaussian() {
        let data = SyntheticSpec::new(150, 40, 4).rho(0.2).snr(5.0).seed(3).generate();
        let mut settings = CvSettings::default();
        settings.n_folds = 5;
        settings.path.path_length = 40;
        settings.threads = 2;
        let cv = cross_validate(
            &data.design,
            &data.response,
            Loss::Gaussian,
            ScreeningKind::Hessian,
            &settings,
        );
        assert_eq!(cv.cv_mean.len(), cv.lambdas.len());
        // The CV minimum is in the interior (not the null model, not the
        // end of the path) for a well-posed high-SNR problem.
        assert!(cv.idx_min > 0, "CV chose the null model");
        // 1-SE λ is at least as large as the min-CV λ.
        assert!(cv.lambda_1se() >= cv.lambda_min());
        // Selected model contains true signals.
        let coefs = cv.selected_coefs(false);
        assert!(!coefs.is_empty());
        let truth = data.beta_true.as_ref().unwrap();
        let hits = coefs
            .iter()
            .filter(|&&(j, _)| truth[j] != 0.0)
            .count();
        assert!(hits >= 3, "only {hits}/4 signals recovered");
    }

    #[test]
    fn cv_logistic_runs() {
        let data = SyntheticSpec::new(120, 20, 3)
            .loss(Loss::Logistic)
            .snr(3.0)
            .signal_scale(1.5)
            .seed(4)
            .generate();
        let mut settings = CvSettings::default();
        settings.n_folds = 4;
        settings.path.path_length = 25;
        settings.threads = 2;
        let cv = cross_validate(
            &data.design,
            &data.response,
            Loss::Logistic,
            ScreeningKind::Working,
            &settings,
        );
        // CV curve finite and the minimum beats the null model's score.
        assert!(cv.cv_mean.iter().all(|v| v.is_finite()));
        assert!(cv.cv_mean[cv.idx_min] < cv.cv_mean[0]);
    }
}
