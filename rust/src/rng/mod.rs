//! Pseudo-random number generation substrate.
//!
//! The offline image has no `rand` crate, so we implement the generators
//! we need ourselves: [`SplitMix64`] for seeding, [`Xoshiro256pp`]
//! (xoshiro256++) as the workhorse generator, plus Gaussian sampling via
//! the Box–Muller transform and utilities for shuffling and sampling
//! that the solver and the data generators rely on.
//!
//! Everything is deterministic given a seed, which the experiment
//! harness exploits to make every figure/table regenerable bit-for-bit.

mod gaussian;

pub use gaussian::GaussianSource;

/// SplitMix64: used to expand a single `u64` seed into the 256-bit state
/// of xoshiro256++. Reference: Steele, Lea & Flood (2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — fast, high-quality, 256-bit state. This is the
/// generator used everywhere in the crate (data generation, coordinate
/// shuffling, property tests).
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed from a single u64 via SplitMix64 (the canonical seeding
    /// procedure recommended by the xoshiro authors).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in the open interval (0, 1) — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Unbiased uniform integer in [0, bound) via Lemire's method.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Standard normal via Box–Muller (uses the cached second variate).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // One-shot Box–Muller; the polar variant would reject, this one
        // does not, and determinism per call-count matters for tests.
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill `out` with i.i.d. standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian();
        }
    }

    /// Poisson sampler. Knuth's product method for small means, PTRS
    /// (transformed rejection) is avoided for code size; for large means
    /// we use the normal approximation with continuity correction, which
    /// is adequate for synthetic-data generation.
    pub fn next_poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.next_gaussian();
            let v = mean + mean.sqrt() * z + 0.5;
            if v < 0.0 {
                0
            } else {
                v.floor() as u64
            }
        }
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn next_bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Derive a child seed from a parent seed and a stream id; used so each
/// experiment repetition/cell gets an independent, reproducible stream.
pub fn derive_seed(parent: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(parent ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
    sm.next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public-domain
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = Xoshiro256pp::seed_from_u64(99);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.next_below(7)] += 1;
        }
        let expected = n / 7;
        for &c in &counts {
            assert!(
                (c as f64 - expected as f64).abs() < 5.0 * (expected as f64).sqrt(),
                "count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = r.next_gaussian();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn poisson_moments_small_and_large_mean() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        for &lam in &[0.5, 4.0, 80.0] {
            let n = 50_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.next_poisson(lam) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lam).abs() < 0.05 * lam.max(1.0),
                "lam={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let s = r.sample_indices(50, 12);
        assert_eq!(s.len(), 12);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*s.last().unwrap() < 50);
    }

    #[test]
    fn derive_seed_streams_differ() {
        let s0 = derive_seed(1, 0);
        let s1 = derive_seed(1, 1);
        let s0b = derive_seed(1, 0);
        assert_eq!(s0, s0b);
        assert_ne!(s0, s1);
    }
}
