//! Regularization-path driver — the paper's Algorithm 2, generalized
//! over all screening strategies so that every method runs on *exactly*
//! the same solver, λ grid, convergence criterion and KKT machinery
//! (the paper's own methodology, §4: "equivalent implementations for
//! all of the methods").
//!
//! Per step λ_k → λ_{k+1} the driver:
//!
//! 1. builds the rule's screened set and the working set `W`;
//! 2. (Hessian) applies the eq.-(7) warm start from the tracked H⁻¹;
//! 3. solves the subproblem on `W` to duality gap ε·ζ;
//! 4. runs KKT checks per the §3.3.4 policy — strong set first, then
//!    the full set, shrinking the candidate set `G` with Gap-Safe
//!    screening after a failed full check;
//! 5. updates the Hessian via Algorithm 1 and records instrumentation
//!    (screened counts, violations, passes, per-phase wall time — the
//!    raw material for every figure in the paper).
//!
//! Stopping follows glmnet/§4: dev-ratio ≥ 0.999, fractional deviance
//! decrease < 10⁻⁵, or saturation (|ever-active| > min(n, p)).

mod homotopy;
mod lambda;

pub use homotopy::{fit_approximate_homotopy, HomotopySettings};
pub use lambda::{default_lambda_min_ratio, lambda_grid};

use crate::hessian::HessianTracker;
use crate::linalg::blas;
use crate::linalg::Design;
use crate::loss::Loss;
use crate::rng::Xoshiro256pp;
use crate::screening::{
    edpp_keep, gap_safe_keep, hessian_screen, sasvi_keep, strong_set, ws_priority, ScreeningKind,
};
use crate::runtime::SweepScratch;
use crate::solver::{solve_subproblem_with, CdSettings, SolveState, SolverScratch};
use std::time::Instant;

/// Path-level settings (defaults = the paper's §4).
#[derive(Clone, Debug)]
pub struct PathSettings {
    /// Number of λ values (paper: 100).
    pub path_length: usize,
    /// λ_min/λ_max; `None` → 10⁻² if p > n else 10⁻⁴ (paper §4).
    pub lambda_min_ratio: Option<f64>,
    /// Explicit λ grid (overrides the log-spaced default when set).
    pub lambda_path: Option<Vec<f64>>,
    /// Hessian-rule unit-bound mixin γ (paper: 0.01).
    pub gamma: f64,
    /// Stop when 1 − dev/dev_null exceeds this (paper: 0.999).
    pub dev_ratio_max: f64,
    /// Stop when the fractional deviance decrease drops below this.
    pub dev_change_min: f64,
    /// §3.3.4 Gap-Safe augmentation of the KKT loop. Honored by every
    /// screening strategy (App. F.3 ablation), and it also gates the
    /// batched look-ahead masks (they are Gap-Safe certificates).
    pub use_gap_safe_aug: bool,
    /// Ablation toggles (App. F.8): eq.-(7) warm starts, Algorithm-1
    /// sweep updates (false → rebuild each step), Hessian screening
    /// (false → working-set strategy with whatever warm start is on).
    pub hessian_warm_starts: bool,
    pub hessian_sweep_updates: bool,
    pub hessian_screening: bool,
    /// GLM Hessian mode: Some(true) = full re-computation each step,
    /// Some(false) = fᵢ″ upper bound + sweep updates, None = the paper's
    /// heuristic `density(X)·n/max(n,p) < 10⁻³ → full` (§3.3.3).
    pub glm_full_hessian: Option<bool>,
    /// Saturation cap on the ever-active count; `None` → min(n, p).
    pub max_ever_active: Option<usize>,
    pub cd: CdSettings,
    pub seed: u64,
}

impl Default for PathSettings {
    fn default() -> Self {
        Self {
            path_length: 100,
            lambda_min_ratio: None,
            lambda_path: None,
            gamma: 0.01,
            dev_ratio_max: 0.999,
            dev_change_min: 1e-5,
            use_gap_safe_aug: true,
            hessian_warm_starts: true,
            hessian_sweep_updates: true,
            hessian_screening: true,
            glm_full_hessian: None,
            max_ever_active: None,
            cd: CdSettings::default(),
            seed: 0,
        }
    }
}

/// Per-step instrumentation (the raw series behind Figures 1, 2, 7, 9,
/// 12–14 and Table 3).
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub lambda: f64,
    /// |W| when the subproblem is first solved (screened set size).
    pub screened: usize,
    /// |W| at convergence.
    pub screened_final: usize,
    pub active: usize,
    /// Coordinate-descent passes (Fig. 2).
    pub passes: usize,
    /// Predictors the rule discarded that turned out KKT-violating.
    pub violations: usize,
    /// Full-set correlation sweeps performed (a batched look-ahead
    /// sweep counts once, on the step that issued it).
    pub full_sweeps: usize,
    /// Whether a look-ahead certificate let this step skip its full
    /// sweep (the first KKT check ran on the pre-shrunk G only).
    pub lookahead_skip: bool,
    /// Candidates removed from G by Gap-Safe shrinks during this step.
    pub g_shrunk: usize,
    pub dev_ratio: f64,
    /// Column shards the engine's backend splits the design into
    /// (1 = unsharded engine, 0 = no engine on this fit).
    pub shards: usize,
    /// Cumulative shard uploads whose staging fully overlapped other
    /// work, snapshotted from the engine's upload pipeline
    /// ([`crate::runtime::UploadStats::overlapped`]; 0 when the
    /// backend uploads synchronously).
    pub upload_overlap: usize,
    /// Wall-clock split (seconds) for the F.10 breakdowns.
    pub t_cd: f64,
    pub t_kkt: f64,
    pub t_hessian: f64,
    pub t_screen: f64,
    /// Kernel-time breakdown (the `--profile` columns). Seconds inside
    /// backend sweep kernels — full KKT sweeps plus batched look-ahead
    /// sweeps — a subset of `t_kkt`.
    pub t_sweep: f64,
    /// Seconds inside Hessian panel formation and Algorithm-1 sweep
    /// algebra ([`HessianTracker`] rebuild/update) — a subset of
    /// `t_hessian`.
    pub t_panel: f64,
    /// Bytes of fresh [`Workspace`] capacity acquired during this step.
    /// Early steps grow the arenas; the allocation-free steady state
    /// reports 0 here.
    pub alloc_bytes: usize,
}

/// Result of a full path fit.
#[derive(Clone, Debug)]
pub struct PathFit {
    pub lambdas: Vec<f64>,
    /// Sparse coefficients per step: (predictor index, value).
    pub betas: Vec<Vec<(usize, f64)>>,
    pub dev_ratios: Vec<f64>,
    pub steps: Vec<StepStats>,
    /// Total wall time in seconds.
    pub total_time: f64,
    pub loss: Loss,
    pub kind: ScreeningKind,
    pub converged: bool,
}

impl PathFit {
    /// Dense coefficient vector at step k.
    pub fn beta_dense(&self, k: usize, p: usize) -> Vec<f64> {
        let mut b = vec![0.0; p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    pub fn total_passes(&self) -> usize {
        self.steps.iter().map(|s| s.passes).sum()
    }

    pub fn total_violations(&self) -> usize {
        self.steps.iter().map(|s| s.violations).sum()
    }

    pub fn mean_screened(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.screened as f64).sum::<f64>() / self.steps.len() as f64
    }
}

/// Fits ℓ₁-regularized GLM paths with a chosen screening strategy.
#[derive(Clone, Debug)]
pub struct PathFitter {
    pub loss: Loss,
    pub kind: ScreeningKind,
    pub settings: PathSettings,
}

/// Internal: indexed set with O(1) membership (bitmap + insertion list).
struct IndexSet {
    member: Vec<bool>,
    items: Vec<usize>,
}

impl IndexSet {
    fn new(p: usize) -> Self {
        Self {
            member: vec![false; p],
            items: Vec::new(),
        }
    }

    #[inline]
    fn insert(&mut self, j: usize) -> bool {
        if self.member[j] {
            false
        } else {
            self.member[j] = true;
            self.items.push(j);
            true
        }
    }

    #[inline]
    fn contains(&self, j: usize) -> bool {
        self.member[j]
    }

    fn len(&self) -> usize {
        self.items.len()
    }

    fn clear(&mut self) {
        for &j in &self.items {
            self.member[j] = false;
        }
        self.items.clear();
    }

    /// Drop every item failing the predicate, keeping insertion order
    /// (in-place twin of filter + assign — no intermediate Vec).
    fn retain(&mut self, mut f: impl FnMut(usize) -> bool) {
        let member = &mut self.member;
        self.items.retain(|&j| {
            if f(j) {
                true
            } else {
                member[j] = false;
                false
            }
        });
    }
}

/// Workspace arena for the path driver: every buffer the steady-state
/// step loop needs, owned in one place and reused across steps (and,
/// via [`PathFitter::fit_with_workspace`], across whole fits). Plain
/// reusable `Vec`s — no allocator tricks — grown to the high-water mark
/// once, then stable; [`StepStats::alloc_bytes`] tracks the growth.
#[derive(Default)]
pub struct Workspace {
    /// Coordinate-descent scratch (threaded into every subproblem).
    solver: SolverScratch,
    /// Backend sweep scratch (`_into` KKT and look-ahead sweeps).
    sweep: SweepScratch,
    /// Current active set (`SolveState::active_set_into`).
    active: Vec<usize>,
    /// Snapshot of `w_set.member` when the step's solve loop starts.
    w_init_member: Vec<bool>,
    /// KKT-violating indices found by the current check.
    violations: Vec<usize>,
    /// Strong-set violations (checked before the full sweep, §3.3.4).
    v_strong: Vec<usize>,
    /// sign(β) on the tracker's active set (Hessian screening).
    signs: Vec<f64>,
    /// Q·signs (eq.-(7) direction), ordered like the tracker.
    qv: Vec<f64>,
    /// Batched look-ahead keep-masks; `la_masks[i]` covers step
    /// `la_start + i` (recycled through the sweep scratch).
    la_masks: Vec<Vec<bool>>,
}

impl Workspace {
    /// Total heap capacity currently held by the arena, in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.solver.capacity_bytes()
            + self.sweep.capacity_bytes()
            + 8 * (self.active.capacity() + self.violations.capacity() + self.v_strong.capacity())
            + 8 * (self.signs.capacity() + self.qv.capacity())
            + self.w_init_member.capacity()
            + self.la_masks.capacity() * std::mem::size_of::<Vec<bool>>()
            + self.la_masks.iter().map(|m| m.capacity()).sum::<usize>()
    }
}

/// Gap-Safe shrink of the candidate set G (§3.3.4), shared by every
/// screening branch of the KKT loop so the call sites cannot drift:
/// keep j iff the sphere test passes at the current iterate or βⱼ ≠ 0.
/// Reuses the correlations already in `c_full` — marginal cost, no
/// extra sweeps. `gap` carries an already-computed duality gap at the
/// same iterate (`None` = compute it here). Returns how many
/// candidates were discarded.
#[allow(clippy::too_many_arguments)]
fn gap_safe_shrink(
    loss: Loss,
    y: &[f64],
    eta: &[f64],
    resid: &[f64],
    beta: &[f64],
    c_full: &[f64],
    col_norms: &[f64],
    xt_inf: f64,
    lambda: f64,
    l1_norm: f64,
    gap: Option<f64>,
    g_set: &mut IndexSet,
) -> usize {
    let scale = lambda.max(xt_inf);
    let gap = gap.unwrap_or_else(|| loss.duality_gap(y, eta, resid, xt_inf, lambda, l1_norm));
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    let before = g_set.len();
    g_set.retain(|j| c_full[j].abs() / scale >= 1.0 - col_norms[j] * radius || beta[j] != 0.0);
    before - g_set.len()
}

impl PathFitter {
    pub fn new(loss: Loss, kind: ScreeningKind) -> Self {
        Self {
            loss,
            kind,
            settings: PathSettings::default(),
        }
    }

    pub fn with_settings(mut self, settings: PathSettings) -> Self {
        self.settings = settings;
        self
    }

    /// Fit the full regularization path (native sweeps only).
    pub fn fit<D: Design + ?Sized>(&self, design: &D, y: &[f64]) -> PathFit {
        self.fit_with_engine(design, y, None)
    }

    /// Fit the path, running full KKT sweeps through a compute backend
    /// ([`crate::runtime::Backend`] — the pure-Rust `NativeBackend`, or
    /// the AOT/PJRT engine under the `pjrt` feature) when one is
    /// provided and has a matching kernel. Falls back to the native f64
    /// sweep per call when the backend path is unavailable. A
    /// row-restricted binding ([`crate::runtime::EngineSweep::fold`])
    /// routes the sweeps through the backend's masked fold kernel —
    /// the cross-validation fold loop passes one of those per fold.
    pub fn fit_with_engine<D: Design + ?Sized>(
        &self,
        design: &D,
        y: &[f64],
        engine: Option<&crate::runtime::EngineSweep>,
    ) -> PathFit {
        let mut ws = Workspace::default();
        self.fit_with_workspace(design, y, engine, &mut ws)
    }

    /// [`Self::fit_with_engine`] with a caller-owned [`Workspace`]:
    /// repeated fits reuse the grown arenas instead of re-allocating
    /// them per path. `cross_validate` holds one workspace per fold
    /// worker (via `Coordinator::run_with`), so folds after a worker's
    /// first report `alloc_bytes ≈ 0` in their [`StepStats`].
    pub fn fit_with_workspace<D: Design + ?Sized>(
        &self,
        design: &D,
        y: &[f64],
        engine: Option<&crate::runtime::EngineSweep>,
        ws: &mut Workspace,
    ) -> PathFit {
        let t_total = Instant::now();
        let n = design.nrows();
        let p = design.ncols();
        assert_eq!(y.len(), n, "response length mismatch");
        if self.kind == ScreeningKind::Edpp {
            assert!(
                matches!(self.loss, Loss::Gaussian),
                "EDPP is defined for the ordinary lasso only"
            );
        }
        let s = &self.settings;
        let loss = self.loss;
        let gap_safe_ok = loss.supports_gap_safe();
        let use_gs_aug = s.use_gap_safe_aug && gap_safe_ok;

        let col_sq_norms: Vec<f64> = (0..p).map(|j| design.col_sq_norm(j)).collect();
        let col_norms: Vec<f64> = col_sq_norms.iter().map(|v| v.sqrt()).collect();
        let zeta = loss.zeta(y);
        let null_dev = loss.null_deviance(y);
        let tol = s.cd.eps * zeta;

        let mut state = SolveState::new(n, p);
        state.refresh(design, y, loss);
        let mut c_full: Vec<f64> = (0..p).map(|j| design.col_dot(j, &state.resid)).collect();
        let lambda_max = blas::amax(&c_full);
        let argmax_col = (0..p)
            .max_by(|&a, &b| c_full[a].abs().total_cmp(&c_full[b].abs()))
            .unwrap_or(0);

        let lambdas = match &s.lambda_path {
            Some(path) => path.clone(),
            None => {
                let ratio = s
                    .lambda_min_ratio
                    .unwrap_or_else(|| default_lambda_min_ratio(n, p));
                lambda_grid(lambda_max, ratio, s.path_length)
            }
        };

        // GLM Hessian mode: the §3.3.3 heuristic unless overridden.
        let glm_full = match (loss, s.glm_full_hessian) {
            (Loss::Gaussian, _) => false,
            (_, Some(v)) => v,
            (_, None) => design.density() * n as f64 / n.max(p) as f64 >= 1e-3,
        };
        // In bound mode the tracker stores the *unweighted* Gram, and
        // eq. (7) rescales by 1/bound (H ≈ bound·XᵀX — §3.3.3).
        let warm_scale = if matches!(loss, Loss::Gaussian) || glm_full {
            1.0
        } else {
            1.0 / loss.weight_upper_bound().unwrap_or(1.0)
        };
        let needs_hessian = self.kind == ScreeningKind::Hessian;
        let mut tracker = HessianTracker::new(n as f64 * 1e-4);
        if let Some(es) = engine {
            // Algorithm-1 Gram panels through the backend (blocked,
            // threaded) instead of per-entry gram_weighted loops —
            // only for exact-f64 backends (panels, unlike sweeps, have
            // no borderline recheck path — H/H⁻¹ must never be built
            // from f32 values) and only when the backend actually
            // parallelizes: the blocked symmetric panel computes the
            // full square, so on a serial backend it would do ~2x the
            // scalar triangle's work.
            if es.engine.is_exact() && es.engine.threads() > 1 {
                tracker = tracker.with_engine(es.engine);
            }
        }
        let mut weights = vec![0.0; n];

        let mut rng = Xoshiro256pp::seed_from_u64(s.seed);
        let mut ever_active = IndexSet::new(p);
        let mut w_set = IndexSet::new(p);
        let mut g_set = IndexSet::new(p); // Gap-Safe candidate set
        let max_ever = s.max_ever_active.unwrap_or(n.min(p));

        let mut fit = PathFit {
            lambdas: Vec::new(),
            betas: Vec::new(),
            dev_ratios: Vec::new(),
            steps: Vec::new(),
            total_time: 0.0,
            loss,
            kind: self.kind,
            converged: true,
        };
        // Step 1 = λmax: the null model (closed form).
        fit.lambdas.push(lambdas[0]);
        fit.betas.push(Vec::new());
        fit.dev_ratios.push(0.0);
        let mut st0 = StepStats {
            lambda: lambdas[0],
            dev_ratio: 0.0,
            passes: 0,
            ..Default::default()
        };
        if let Some(es) = engine {
            st0.shards = es.engine.shards();
            st0.upload_overlap = es.engine.upload_stats().map_or(0, |u| u.overlapped);
        }
        fit.steps.push(st0);

        let mut prev_active: Vec<usize> = Vec::new();
        let mut prev_dev_ratio = 0.0;
        let mut scratch_u = vec![0.0; n];

        // Batched look-ahead screening (Larsson 2021; see
        // `crate::screening::lookahead_keep`): keep-masks for upcoming
        // λ steps from the last batched sweep live in `ws.la_masks`;
        // `ws.la_masks[i]` covers step `la_start + i`.
        ws.la_masks.clear();
        let mut la_start = 0usize;
        // Arena high-water mark for the per-step alloc-bytes profile.
        let mut ws_cap = ws.capacity_bytes();

        for k in 1..lambdas.len() {
            let lp = lambdas[k - 1];
            let ln = lambdas[k];
            let mut st = StepStats {
                lambda: ln,
                ..Default::default()
            };
            if let Some(es) = engine {
                st.shards = es.engine.shards();
                st.upload_overlap = es.engine.upload_stats().map_or(0, |u| u.overlapped);
            }

            // ---------------- screening + warm start ----------------
            let t0 = Instant::now();
            let strong = strong_set(&c_full, lp, ln);
            w_set.clear();
            match self.kind {
                ScreeningKind::Hessian => {
                    // v = Q·sign(β_A); u = (D(w)) X_A v.
                    let tr_active = tracker.active();
                    ws.signs.clear();
                    ws.signs
                        .extend(tr_active.iter().map(|&j| state.beta[j].signum()));
                    tracker.q_times_into(&ws.signs, &mut ws.qv);
                    let v = &ws.qv;
                    scratch_u.iter_mut().for_each(|x| *x = 0.0);
                    for (idx, &j) in tr_active.iter().enumerate() {
                        design.col_axpy(j, v[idx], &mut scratch_u);
                    }
                    if glm_full && !matches!(loss, Loss::Gaussian) {
                        loss.weights_into(&state.eta, &mut weights);
                        for i in 0..n {
                            scratch_u[i] *= weights[i];
                        }
                    }
                    if s.hessian_screening {
                        let kept = hessian_screen(
                            design,
                            &c_full,
                            &scratch_u,
                            &prev_active,
                            lp,
                            ln,
                            s.gamma,
                        );
                        for j in kept {
                            w_set.insert(j);
                        }
                    }
                    // Union with the ever-active set (§3.3).
                    for &j in &ever_active.items {
                        w_set.insert(j);
                    }
                    // Warm start, eq. (7).
                    if s.hessian_warm_starts {
                        for (idx, &j) in tr_active.iter().enumerate() {
                            state.beta[j] += (lp - ln) * warm_scale * v[idx];
                        }
                    }
                }
                ScreeningKind::Strong => {
                    for &j in &strong {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::Working => {
                    for &j in &ever_active.items {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::GapSafe => {
                    // Sequential Gap Safe from the previous solution.
                    let scale = ln.max(blas::amax(&c_full));
                    let xt_theta: Vec<f64> = c_full.iter().map(|c| c / scale).collect();
                    let gap = loss.duality_gap(
                        y,
                        &state.eta,
                        &state.resid,
                        blas::amax(&c_full),
                        ln,
                        state.l1_norm(),
                    );
                    let cols: Vec<usize> = (0..p).collect();
                    let kept = gap_safe_keep(&xt_theta, &cols, &col_norms, gap, ln);
                    for j in kept {
                        w_set.insert(j);
                    }
                    for &j in &prev_active {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::Edpp => {
                    let theta_prev: Vec<f64> = state.resid.iter().map(|r| r / lp).collect();
                    let kept = edpp_keep(
                        design,
                        y,
                        &theta_prev,
                        lp,
                        ln,
                        k == 1,
                        argmax_col,
                        &col_norms,
                    );
                    for j in kept {
                        w_set.insert(j);
                    }
                    for &j in &prev_active {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::Sasvi => {
                    let scale = ln.max(blas::amax(&c_full));
                    let theta0: Vec<f64> = state.resid.iter().map(|r| r / scale).collect();
                    let kept = sasvi_keep(design, y, &theta0, ln, &col_norms);
                    for j in kept {
                        w_set.insert(j);
                    }
                    for &j in &prev_active {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::Celer | ScreeningKind::Blitz => {
                    // Initial working set: previous active + the top
                    // strong-set priorities, sized 2·|A| (min 10).
                    let target = (2 * prev_active.len()).max(10).min(p);
                    for &j in &prev_active {
                        w_set.insert(j);
                    }
                    let scale = ln.max(blas::amax(&c_full));
                    let mut cand: Vec<(f64, usize)> = strong
                        .iter()
                        .filter(|&&j| !w_set.contains(j))
                        .map(|&j| (ws_priority(c_full[j] / scale, col_norms[j]), j))
                        .collect();
                    cand.sort_by(|a, b| a.0.total_cmp(&b.0));
                    for (_, j) in cand.into_iter().take(target.saturating_sub(w_set.len())) {
                        w_set.insert(j);
                    }
                }
                ScreeningKind::None => {
                    for j in 0..p {
                        w_set.insert(j);
                    }
                }
            }
            st.t_screen += t0.elapsed().as_secs_f64();
            st.screened = w_set.len();
            ws.w_init_member.clear();
            ws.w_init_member.extend_from_slice(&w_set.member);

            // Reset the Gap-Safe candidate set (Alg. 2 line 14) — or,
            // when a look-ahead certificate covers this λ, pre-shrink
            // it: predictors outside the mask are provably inactive at
            // ln, so the first KKT check can run on G alone and the
            // full sweep is skipped entirely. Celer/Blitz are excluded:
            // their termination is gap-driven, and without a full sweep
            // the dual scale ‖Xᵀr‖∞ is only known over G, which could
            // understate the gap and stop them early.
            g_set.clear();
            let la_eligible = use_gs_aug
                && !matches!(self.kind, ScreeningKind::Celer | ScreeningKind::Blitz);
            let la_mask = if la_eligible && k >= la_start {
                ws.la_masks.get(k - la_start)
            } else {
                None
            };
            let lookahead_hit = match la_mask {
                Some(mask) => {
                    for j in 0..p {
                        if mask[j] || w_set.contains(j) || ever_active.contains(j) {
                            g_set.insert(j);
                        }
                    }
                    true
                }
                None => {
                    for j in 0..p {
                        g_set.insert(j);
                    }
                    false
                }
            };
            st.lookahead_skip = lookahead_hit;

            // ---------------- inner solve/check loop ----------------
            let mut first_full_done = lookahead_hit;
            let mut ws_growth = (2 * w_set.len()).max(20);
            // Stall guard: when the subproblem cannot reach the duality
            // gap tolerance (numerically unreachable ε) and no KKT
            // violations remain, repeating the solve cannot help —
            // accept the solution and mark the fit non-converged.
            let mut stalls = 0usize;
            loop {
                let t_cd = Instant::now();
                let res = solve_subproblem_with(
                    design,
                    y,
                    loss,
                    ln,
                    &w_set.items,
                    &mut state,
                    &col_sq_norms,
                    zeta,
                    &s.cd,
                    &mut rng,
                    &mut ws.solver,
                );
                st.t_cd += t_cd.elapsed().as_secs_f64();
                st.passes += res.passes;

                let t_kkt = Instant::now();
                match self.kind {
                    ScreeningKind::Hessian | ScreeningKind::Working => {
                        // §3.3.4: strong set first.
                        ws.v_strong.clear();
                        for &j in &strong {
                            if !w_set.contains(j) && g_set.contains(j) {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                if c.abs() > ln {
                                    ws.v_strong.push(j);
                                }
                            }
                        }
                        if !ws.v_strong.is_empty() {
                            for &j in &ws.v_strong {
                                if !ws.w_init_member[j] {
                                    st.violations += 1;
                                }
                                w_set.insert(j);
                            }
                            st.t_kkt += t_kkt.elapsed().as_secs_f64();
                            continue;
                        }
                        // Full (or Gap-Safe-restricted) check.
                        ws.violations.clear();
                        let mut xt_inf = 0.0f64;
                        if !first_full_done {
                            let t_sw = Instant::now();
                            let via_engine = engine
                                .map(|es| {
                                    es.full_sweep_into(
                                        design,
                                        y,
                                        &state.eta,
                                        &state.resid,
                                        ln,
                                        &mut c_full,
                                        &mut ws.sweep,
                                    )
                                })
                                .unwrap_or(false);
                            if via_engine {
                                st.t_sweep += t_sw.elapsed().as_secs_f64();
                                for (j, c) in c_full.iter().enumerate() {
                                    xt_inf = xt_inf.max(c.abs());
                                    if !w_set.contains(j) && c.abs() > ln {
                                        ws.violations.push(j);
                                    }
                                }
                            } else {
                                for j in 0..p {
                                    let c = design.col_dot(j, &state.resid);
                                    c_full[j] = c;
                                    xt_inf = xt_inf.max(c.abs());
                                    if !w_set.contains(j) && c.abs() > ln {
                                        ws.violations.push(j);
                                    }
                                }
                            }
                            st.full_sweeps += 1;
                            first_full_done = true;
                        } else {
                            for &j in &g_set.items {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                xt_inf = xt_inf.max(c.abs());
                                if !w_set.contains(j) && c.abs() > ln {
                                    ws.violations.push(j);
                                }
                            }
                        }
                        if ws.violations.is_empty() && res.converged {
                            st.t_kkt += t_kkt.elapsed().as_secs_f64();
                            break;
                        }
                        // Skipped on look-ahead-covered steps: without a
                        // full sweep this step, xt_inf is known over G
                        // only, so θ = r/max(λ, xt_inf) is not provably
                        // dual-feasible and the sphere radius could
                        // over-shrink. The mask itself was built from a
                        // *global* sup-norm at the batch point, so G is
                        // already soundly shrunk.
                        if use_gs_aug && !lookahead_hit {
                            st.g_shrunk += gap_safe_shrink(
                                loss,
                                y,
                                &state.eta,
                                &state.resid,
                                &state.beta,
                                &c_full,
                                &col_norms,
                                xt_inf,
                                ln,
                                state.l1_norm(),
                                None,
                                &mut g_set,
                            );
                        }
                        if ws.violations.is_empty() {
                            // KKT-clean but gap not under tol: retry CD a
                            // bounded number of times, then accept.
                            stalls += 1;
                            if res.converged || stalls >= 3 {
                                if !res.converged {
                                    fit.converged = false;
                                }
                                st.t_kkt += t_kkt.elapsed().as_secs_f64();
                                break;
                            }
                        } else {
                            stalls = 0;
                        }
                        for &j in &ws.violations {
                            if !ws.w_init_member[j] {
                                st.violations += 1;
                            }
                            w_set.insert(j);
                        }
                    }
                    ScreeningKind::Strong
                    | ScreeningKind::GapSafe
                    | ScreeningKind::Edpp
                    | ScreeningKind::Sasvi
                    | ScreeningKind::None => {
                        ws.violations.clear();
                        let iter_all = !first_full_done;
                        let mut xt_inf = 0.0f64;
                        let t_sw = Instant::now();
                        let via_engine = iter_all
                            && engine
                                .map(|es| {
                                    es.full_sweep_into(
                                        design,
                                        y,
                                        &state.eta,
                                        &state.resid,
                                        ln,
                                        &mut c_full,
                                        &mut ws.sweep,
                                    )
                                })
                                .unwrap_or(false);
                        if via_engine {
                            st.t_sweep += t_sw.elapsed().as_secs_f64();
                            for (j, c) in c_full.iter().enumerate() {
                                xt_inf = xt_inf.max(c.abs());
                                if !w_set.contains(j) && c.abs() > ln {
                                    ws.violations.push(j);
                                }
                            }
                        } else if iter_all {
                            for j in 0..p {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                xt_inf = xt_inf.max(c.abs());
                                if !w_set.contains(j) && c.abs() > ln {
                                    ws.violations.push(j);
                                }
                            }
                        } else {
                            for &j in &g_set.items {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                xt_inf = xt_inf.max(c.abs());
                                if !w_set.contains(j) && c.abs() > ln {
                                    ws.violations.push(j);
                                }
                            }
                        }
                        if iter_all {
                            st.full_sweeps += 1;
                            first_full_done = true;
                        }
                        if ws.violations.is_empty() {
                            stalls += 1;
                            if res.converged || stalls >= 3 {
                                if !res.converged {
                                    fit.converged = false;
                                }
                                st.t_kkt += t_kkt.elapsed().as_secs_f64();
                                break;
                            }
                        } else {
                            stalls = 0;
                        }
                        // §3.3.4 augmentation — honors the App. F.3
                        // ablation toggle, not just loss support
                        // (`use_gs_aug`, not `gap_safe_ok`). Skipped on
                        // look-ahead-covered steps (restricted xt_inf —
                        // see the Hessian/Working branch).
                        if use_gs_aug && !lookahead_hit {
                            st.g_shrunk += gap_safe_shrink(
                                loss,
                                y,
                                &state.eta,
                                &state.resid,
                                &state.beta,
                                &c_full,
                                &col_norms,
                                xt_inf,
                                ln,
                                state.l1_norm(),
                                None,
                                &mut g_set,
                            );
                        }
                        for &j in &ws.violations {
                            if !ws.w_init_member[j] {
                                st.violations += 1;
                            }
                            w_set.insert(j);
                        }
                    }
                    ScreeningKind::Celer | ScreeningKind::Blitz => {
                        // Dynamic working-set methods: global gap check,
                        // Gap-Safe screen, prioritized re-selection.
                        let mut xt_inf = 0.0f64;
                        let t_sw = Instant::now();
                        let via_engine = !first_full_done
                            && engine
                                .map(|es| {
                                    es.full_sweep_into(
                                        design,
                                        y,
                                        &state.eta,
                                        &state.resid,
                                        ln,
                                        &mut c_full,
                                        &mut ws.sweep,
                                    )
                                })
                                .unwrap_or(false);
                        if via_engine {
                            st.t_sweep += t_sw.elapsed().as_secs_f64();
                            for c in &c_full {
                                xt_inf = xt_inf.max(c.abs());
                            }
                        } else if !first_full_done {
                            for j in 0..p {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                xt_inf = xt_inf.max(c.abs());
                            }
                        } else {
                            for &j in &g_set.items {
                                let c = design.col_dot(j, &state.resid);
                                c_full[j] = c;
                                xt_inf = xt_inf.max(c.abs());
                            }
                        }
                        if !first_full_done {
                            st.full_sweeps += 1;
                            first_full_done = true;
                        }
                        let gap = loss.duality_gap(
                            y,
                            &state.eta,
                            &state.resid,
                            xt_inf,
                            ln,
                            state.l1_norm(),
                        );
                        if gap <= tol {
                            st.t_kkt += t_kkt.elapsed().as_secs_f64();
                            break;
                        }
                        if w_set.len() >= g_set.len().min(p) {
                            // Working set already covers every candidate:
                            // the subproblem IS the full problem, so a
                            // stalled gap cannot improve by re-selection.
                            stalls += 1;
                            if stalls >= 3 {
                                fit.converged = false;
                                st.t_kkt += t_kkt.elapsed().as_secs_f64();
                                break;
                            }
                        }
                        let scale = ln.max(xt_inf);
                        // Same ablation-toggle fix as above: honor
                        // `use_gap_safe_aug` for Celer/Blitz too.
                        if use_gs_aug {
                            st.g_shrunk += gap_safe_shrink(
                                loss,
                                y,
                                &state.eta,
                                &state.resid,
                                &state.beta,
                                &c_full,
                                &col_norms,
                                xt_inf,
                                ln,
                                state.l1_norm(),
                                Some(gap),
                                &mut g_set,
                            );
                        }
                        // New working set: active ∪ top-priority from G.
                        state.active_set_into(&mut ws.active);
                        let mut cand: Vec<(f64, usize)> = g_set
                            .items
                            .iter()
                            .copied()
                            .filter(|&j| state.beta[j] == 0.0)
                            .map(|j| (ws_priority(c_full[j] / scale, col_norms[j]), j))
                            .collect();
                        cand.sort_by(|a, b| a.0.total_cmp(&b.0));
                        w_set.clear();
                        for &j in &ws.active {
                            w_set.insert(j);
                        }
                        for (_, j) in cand
                            .into_iter()
                            .take(ws_growth.saturating_sub(w_set.len()))
                        {
                            w_set.insert(j);
                        }
                        ws_growth *= 2;
                    }
                }
                st.t_kkt += t_kkt.elapsed().as_secs_f64();
            }

            // ---------------- bookkeeping ----------------
            st.screened_final = w_set.len();
            state.active_set_into(&mut ws.active);
            st.active = ws.active.len();
            for &j in &ws.active {
                ever_active.insert(j);
            }

            // Paranoid: re-derive the full correlation vector at the
            // accepted iterate and check every screened-out predictor
            // against the Gap-Safe ball bound (`crate::invariants`). A
            // violation means an active predictor was wrongly discarded.
            // Gated on losses with a valid gap-safe dual ball.
            #[cfg(feature = "paranoid")]
            if gap_safe_ok {
                let c_chk: Vec<f64> = (0..p).map(|j| design.col_dot(j, &state.resid)).collect();
                let xt_chk = blas::amax(&c_chk);
                let gap_chk =
                    loss.duality_gap(y, &state.eta, &state.resid, xt_chk, ln, state.l1_norm());
                crate::invariants::assert_screened_sound(
                    &c_chk,
                    &col_norms,
                    &w_set.member,
                    ln,
                    gap_chk,
                );
            }

            // Update H / H⁻¹ (Algorithm 1) for the next step.
            if needs_hessian {
                let t_h = Instant::now();
                if matches!(loss, Loss::Gaussian) || !glm_full {
                    if s.hessian_sweep_updates && tracker.dim() > 0 {
                        tracker.update(design, &ws.active, None);
                    } else {
                        tracker.rebuild(design, &ws.active, None);
                    }
                } else {
                    loss.weights_into(&state.eta, &mut weights);
                    tracker.rebuild(design, &ws.active, Some(&weights));
                }
                st.t_hessian += t_h.elapsed().as_secs_f64();
                st.t_panel += tracker.take_panel_seconds();
            }

            let dev = loss.deviance(y, &state.eta);
            let dev_ratio = 1.0 - dev / null_dev.max(1e-300);
            st.dev_ratio = dev_ratio;

            // Mirrors the stopping rules evaluated below, so the final
            // step does not waste a batched sweep whose masks would be
            // discarded immediately.
            let will_stop = dev_ratio >= s.dev_ratio_max
                || (k > 1
                    && (dev_ratio - prev_dev_ratio)
                        < s.dev_change_min * dev_ratio.abs().max(1e-12))
                || ever_active.len() > max_ever;

            // Batched look-ahead refresh: when the mask window is
            // exhausted, one batched sweep at this step's solution
            // serves the KKT checks of the next `lookahead` steps and
            // refreshes the whole correlation vector (it *is* a full
            // sweep — counted as such here).
            if la_eligible
                && self.kind != ScreeningKind::None
                && k + 1 < lambdas.len()
                && !will_stop
            {
                if let Some(es) = engine {
                    if es.lookahead > 0 && k + 1 >= la_start + ws.la_masks.len() {
                        let t_b = Instant::now();
                        let hi = (k + 1 + es.lookahead).min(lambdas.len());
                        if es.look_ahead_into(
                            design,
                            y,
                            &state.eta,
                            &state.resid,
                            state.l1_norm(),
                            &lambdas[k + 1..hi],
                            &mut c_full,
                            &mut ws.la_masks,
                            &mut ws.sweep,
                        ) {
                            la_start = k + 1;
                            st.full_sweeps += 1;
                        }
                        let dt = t_b.elapsed().as_secs_f64();
                        st.t_kkt += dt;
                        st.t_sweep += dt;
                    }
                }
            }

            fit.lambdas.push(ln);
            fit.betas
                .push(ws.active.iter().map(|&j| (j, state.beta[j])).collect());
            fit.dev_ratios.push(dev_ratio);
            let cap_now = ws.capacity_bytes();
            st.alloc_bytes = cap_now.saturating_sub(ws_cap);
            ws_cap = cap_now;
            fit.steps.push(st);
            prev_active.clear();
            prev_active.extend_from_slice(&ws.active);

            // Stopping rules (glmnet / §4).
            if dev_ratio >= s.dev_ratio_max {
                break;
            }
            if k > 1 && (dev_ratio - prev_dev_ratio) < s.dev_change_min * dev_ratio.abs().max(1e-12)
            {
                break;
            }
            prev_dev_ratio = dev_ratio;
            if ever_active.len() > max_ever {
                break;
            }
        }

        fit.total_time = t_total.elapsed().as_secs_f64();
        fit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SyntheticSpec};
    use crate::testkit::all_close;

    fn fit_pair(
        kind_a: ScreeningKind,
        kind_b: ScreeningKind,
        loss: Loss,
        n: usize,
        p: usize,
    ) -> (PathFit, PathFit, usize) {
        let mut spec = SyntheticSpec::new(n, p, 5).rho(0.3).snr(2.0).loss(loss).seed(33);
        if matches!(loss, Loss::Poisson) {
            spec = spec.signal_scale(0.3);
        }
        let data = spec.generate();
        let mut settings = PathSettings::default();
        settings.path_length = 30;
        // Tight tolerance so that "same solution" comparisons are not
        // dominated by solver slack.
        settings.cd.eps = 1e-8;
        let a = PathFitter::new(loss, kind_a)
            .with_settings(settings.clone())
            .fit(&data.design, &data.response);
        let b = PathFitter::new(loss, kind_b)
            .with_settings(settings)
            .fit(&data.design, &data.response);
        (a, b, p)
    }

    fn assert_same_solutions(a: &PathFit, b: &PathFit, p: usize, tol: f64) {
        let m = a.lambdas.len().min(b.lambdas.len());
        assert!(m > 5, "paths too short: {} vs {}", a.lambdas.len(), b.lambdas.len());
        for k in 0..m {
            let ba = a.beta_dense(k, p);
            let bb = b.beta_dense(k, p);
            all_close(&ba, &bb, tol, tol).unwrap_or_else(|e| {
                panic!("step {k} (λ={}): {e}", a.lambdas[k]);
            });
        }
    }

    #[test]
    fn hessian_matches_none_gaussian() {
        let (a, b, p) = fit_pair(ScreeningKind::Hessian, ScreeningKind::None, Loss::Gaussian, 60, 40);
        assert_same_solutions(&a, &b, p, 2e-3);
    }

    #[test]
    fn strong_and_working_match_gaussian() {
        let (a, b, p) = fit_pair(ScreeningKind::Strong, ScreeningKind::Working, Loss::Gaussian, 50, 80);
        assert_same_solutions(&a, &b, p, 2e-3);
    }

    #[test]
    fn celer_blitz_match_gaussian() {
        let (a, b, p) = fit_pair(ScreeningKind::Celer, ScreeningKind::Blitz, Loss::Gaussian, 50, 80);
        assert_same_solutions(&a, &b, p, 2e-3);
    }

    #[test]
    fn safe_rules_match_gaussian() {
        let (a, b, p) = fit_pair(ScreeningKind::GapSafe, ScreeningKind::Edpp, Loss::Gaussian, 50, 60);
        assert_same_solutions(&a, &b, p, 2e-3);
        let (c, d, p2) = fit_pair(ScreeningKind::Sasvi, ScreeningKind::None, Loss::Gaussian, 50, 60);
        assert_same_solutions(&c, &d, p2, 2e-3);
    }

    #[test]
    fn hessian_matches_working_logistic() {
        let (a, b, p) = fit_pair(ScreeningKind::Hessian, ScreeningKind::Working, Loss::Logistic, 80, 40);
        assert_same_solutions(&a, &b, p, 5e-3);
    }

    #[test]
    fn hessian_matches_working_poisson() {
        let (a, b, p) = fit_pair(ScreeningKind::Hessian, ScreeningKind::Working, Loss::Poisson, 80, 30);
        assert_same_solutions(&a, &b, p, 5e-3);
    }

    #[test]
    fn path_monotone_dev_ratio_and_growing_support() {
        let data = SyntheticSpec::new(100, 50, 5).rho(0.4).snr(3.0).seed(1).generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        // dev ratio is non-decreasing along a lasso path
        for w in fit.dev_ratios.windows(2) {
            assert!(w[1] >= w[0] - 1e-8, "dev ratio decreased: {w:?}");
        }
        // first step is the null model
        assert!(fit.betas[0].is_empty());
        assert!(fit.dev_ratios.last().unwrap() > &0.5);
    }

    #[test]
    fn screened_set_smaller_than_p_for_hessian() {
        let data = SyntheticSpec::new(50, 300, 5).rho(0.5).snr(2.0).seed(5).generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        let mean = fit.mean_screened();
        assert!(mean < 150.0, "hessian screened too much: {mean}");
    }

    #[test]
    fn hessian_fewer_screened_than_strong_high_correlation() {
        let data = SyntheticSpec::new(50, 400, 5).rho(0.8).snr(2.0).seed(9).generate();
        let mut settings = PathSettings::default();
        settings.path_length = 40;
        let h = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .with_settings(settings.clone())
            .fit(&data.design, &data.response);
        let s = PathFitter::new(Loss::Gaussian, ScreeningKind::Strong)
            .with_settings(settings)
            .fit(&data.design, &data.response);
        assert!(
            h.mean_screened() < s.mean_screened(),
            "hessian {} vs strong {}",
            h.mean_screened(),
            s.mean_screened()
        );
    }

    #[test]
    fn warm_starts_reduce_passes() {
        let data = SyntheticSpec::new(200, 30, 5).snr(5.0).seed(11).generate();
        let mut on = PathSettings::default();
        on.path_length = 50;
        let mut off = on.clone();
        off.hessian_warm_starts = false;
        let with_ws = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .with_settings(on)
            .fit(&data.design, &data.response);
        let without = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .with_settings(off)
            .fit(&data.design, &data.response);
        assert!(
            with_ws.total_passes() <= without.total_passes(),
            "warm {} vs cold {}",
            with_ws.total_passes(),
            without.total_passes()
        );
    }

    #[test]
    fn explicit_lambda_path_respected() {
        let data = SyntheticSpec::new(40, 20, 3).seed(2).generate();
        let mut settings = PathSettings::default();
        settings.lambda_path = Some(vec![1.0, 0.5, 0.25]);
        // λs are on the standardized scale; rescale by the data's λmax.
        let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian).with_settings(settings);
        let fit = fitter.fit(&data.design, &data.response);
        // The fitted grid must be exactly the explicit path (a prefix
        // only if a stopping rule fires early).
        let expected = [1.0, 0.5, 0.25];
        assert!(
            (2..=3).contains(&fit.lambdas.len()),
            "unexpected path length {}",
            fit.lambdas.len()
        );
        for (k, &l) in fit.lambdas.iter().enumerate() {
            assert_eq!(l, expected[k], "step {k}");
        }
    }

    #[test]
    fn gap_safe_aug_toggle_honored_by_all_strategies() {
        // Regression: `use_gap_safe_aug = false` used to be ignored
        // outside the Hessian/Working branch (the shrink was gated on
        // loss support only). With the toggle off, no strategy may
        // shrink G; with it on, Strong on a correlated design must.
        let data = SyntheticSpec::new(50, 300, 5).rho(0.6).snr(2.0).seed(7).generate();
        for kind in [
            ScreeningKind::Strong,
            ScreeningKind::GapSafe,
            ScreeningKind::Celer,
            ScreeningKind::Hessian,
        ] {
            let mut off = PathSettings::default();
            off.path_length = 25;
            off.use_gap_safe_aug = false;
            let fit = PathFitter::new(Loss::Gaussian, kind)
                .with_settings(off)
                .fit(&data.design, &data.response);
            let shrunk: usize = fit.steps.iter().map(|s| s.g_shrunk).sum();
            assert_eq!(shrunk, 0, "{kind}: G was shrunk with the ablation off");
        }
        let mut on = PathSettings::default();
        on.path_length = 25;
        // Celer iterates its KKT loop every step (working set grows
        // from small), so with the toggle on it must shrink G.
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Celer)
            .with_settings(on)
            .fit(&data.design, &data.response);
        let shrunk: usize = fit.steps.iter().map(|s| s.g_shrunk).sum();
        assert!(shrunk > 0, "Celer with aug on never shrank G");
    }

    #[test]
    fn sparse_design_path_fits() {
        let data = SyntheticSpec::new(100, 200, 8).density(0.05).seed(3).generate();
        let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
            .fit(&data.design, &data.response);
        let fit2 = PathFitter::new(Loss::Gaussian, ScreeningKind::Working)
            .fit(&data.design, &data.response);
        assert_same_solutions(&fit, &fit2, 200, 5e-3);
    }

    #[test]
    #[should_panic(expected = "EDPP")]
    fn edpp_rejects_logistic() {
        let data = SyntheticSpec::new(30, 10, 2).loss(Loss::Logistic).seed(1).generate();
        let _ = PathFitter::new(Loss::Logistic, ScreeningKind::Edpp)
            .fit(&data.design, &data.response);
    }
}
