//! Compute-backend runtime for the solve path's full KKT sweeps.
//!
//! The path driver ([`crate::path::PathFitter::fit_with_engine`]) can
//! route its hot full-set operations — the correlation sweep c = Xᵀr,
//! the fused KKT sweep, and the weighted Gram panels of Algorithm 1 —
//! through a [`Backend`]:
//!
//! * [`NativeBackend`] (always available, the default): pure-Rust f64
//!   kernels on top of [`crate::linalg`]. Zero dependencies, exact —
//!   the reference implementation every other backend is checked
//!   against.
//! * `PjrtBackend` (behind the **`pjrt`** cargo feature): executes the
//!   AOT artifacts produced by `python/compile/aot.py` (HLO text) on a
//!   PJRT client. The engine code type-checks against the in-tree
//!   `xla_stub` shim, so no XLA toolchain is needed to *build*;
//!   wiring a real `xla`-crate client in is a linking concern, not an
//!   API one (see README "Feature matrix").
//!
//! Precision contract: backends may compute in f32 (the AOT artifacts
//! do). [`EngineSweep::full_sweep`] therefore re-verifies every
//! *borderline* correlation (within `recheck_band` of the screening
//! threshold) with the native f64 path, so KKT decisions never depend
//! on reduced-precision rounding.

use crate::error::Result;
use crate::linalg::Design;
use crate::loss::Loss;
use std::path::Path;

mod native;
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// A design registered with (uploaded to) a backend. Holds the
/// backend-specific representation plus the logical shape.
pub struct RegisteredDesign {
    pub n: usize,
    pub p: usize,
    pub(crate) repr: DesignRepr,
}

pub(crate) enum DesignRepr {
    /// Column-major (n, p) f64 copy owned by the native backend.
    Native(Vec<f64>),
    #[cfg(feature = "pjrt")]
    Pjrt(xla_stub::PjRtBuffer),
}

/// The operations a compute backend provides to the path driver.
///
/// Every method that depends on a compiled artifact returns
/// `Ok(None)` when the backend has nothing for the requested
/// (op, shape); the caller then falls back to the native sweep.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Number of ops this backend can serve (compiled artifacts for
    /// PJRT; the fixed native op set otherwise).
    fn num_ops(&self) -> usize;

    /// Whether a fused KKT sweep is available for this loss and shape.
    fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool;

    /// Whether this backend computes in exact f64. Exact backends skip
    /// the borderline re-verification in [`EngineSweep::full_sweep`];
    /// reduced-precision backends (f32 artifacts) must leave this
    /// false.
    fn is_exact(&self) -> bool {
        false
    }

    /// Register a design from its raw column-major f64 buffer.
    /// O(np), once per dataset.
    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign>;

    /// c = Xᵀr. `None` when the backend has no kernel for this shape.
    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>>;

    /// Fused KKT sweep: returns (c, pseudo-residual) at the given
    /// linear predictor, or `None` when unavailable for this
    /// (loss, shape).
    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>>;

    /// Weighted Gram panel X_E D(w) X_Dᵀ (row-major (e, d)), the
    /// Algorithm-1 augmentation block. `xe_t`/`xd_t` are (e, n)/(d, n)
    /// row-major f64 slices.
    fn gram_block(
        &self,
        xe_t: &[f64],
        w: &[f64],
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>>;
}

/// The runtime engine: a [`Backend`] behind a stable, object-safe
/// front the rest of the crate (path driver, CLI, benches) talks to.
pub struct RuntimeEngine {
    backend: Box<dyn Backend>,
}

impl RuntimeEngine {
    /// The pure-Rust backend. Always available, needs no artifacts.
    pub fn native() -> Self {
        Self {
            backend: Box::new(NativeBackend),
        }
    }

    /// Wrap an arbitrary backend implementation.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        Self { backend }
    }

    /// Load and compile every AOT artifact listed in `dir`/manifest.tsv
    /// (PJRT). Without the `pjrt` feature this always errors: the
    /// default build ships no artifact executor, only [`Self::native`].
    #[cfg(feature = "pjrt")]
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Ok(Self {
            backend: Box::new(pjrt::PjrtBackend::load_dir(dir)?),
        })
    }

    /// See the `pjrt`-enabled variant; this build has no PJRT engine.
    #[cfg(not(feature = "pjrt"))]
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Err(crate::err!(
            "built without the `pjrt` feature: cannot load artifacts from {} \
             (use RuntimeEngine::native(), or rebuild with --features pjrt)",
            dir.display()
        ))
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Self> {
        Self::load_dir(Path::new("artifacts"))
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn num_ops(&self) -> usize {
        self.backend.num_ops()
    }

    /// Whether a KKT sweep is available for this loss and shape.
    pub fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        self.backend.supports_sweep(loss, n, p)
    }

    /// Whether the backend computes in exact f64.
    pub fn is_exact(&self) -> bool {
        self.backend.is_exact()
    }

    /// Upload a design (as its raw column-major f64 buffer).
    pub fn register_design(
        &self,
        col_major: &[f64],
        n: usize,
        p: usize,
    ) -> Result<RegisteredDesign> {
        self.backend.register_design(col_major, n, p)
    }

    /// c = Xᵀr; `None` when no kernel matches the shape.
    pub fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        self.backend.correlation(design, r)
    }

    /// Fused KKT sweep; `None` when unavailable for (loss, shape).
    pub fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        self.backend.kkt_sweep(loss, design, y, eta, lambda)
    }

    /// Weighted Gram panel (Algorithm-1 augmentation).
    pub fn gram_block(
        &self,
        xe_t: &[f64],
        w: &[f64],
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        self.backend.gram_block(xe_t, w, xd_t, e, d, n)
    }
}

/// An engine bound to one registered design: what the path driver uses
/// for its full KKT sweeps ([`crate::path::PathFitter::fit_with_engine`]).
pub struct EngineSweep<'a> {
    pub engine: &'a RuntimeEngine,
    pub design: RegisteredDesign,
    pub loss: Loss,
    /// Borderline band re-verified in f64 (fraction of λ). Irrelevant
    /// for exact-f64 backends, load-bearing for f32 artifact backends.
    pub recheck_band: f64,
}

impl<'a> EngineSweep<'a> {
    /// Bind `engine` to a dense design; returns None when the engine
    /// has no sweep kernel for this (loss, n, p).
    pub fn new(
        engine: &'a RuntimeEngine,
        design: &crate::linalg::DenseMatrix,
        loss: Loss,
    ) -> Result<Option<Self>> {
        let (n, p) = (design.nrows(), design.ncols());
        if !engine.supports_sweep(loss, n, p) {
            return Ok(None);
        }
        let reg = engine.register_design(design.data(), n, p)?;
        Ok(Some(Self {
            engine,
            design: reg,
            loss,
            recheck_band: 1e-3,
        }))
    }

    /// Full correlation sweep through the backend, with native f64
    /// re-verification of the borderline band around λ. Returns false
    /// (leaving `c` untouched) when the backend path is unavailable,
    /// in which case the caller falls back to the native sweep.
    pub fn full_sweep<D: Design + ?Sized>(
        &self,
        native: &D,
        y: &[f64],
        eta: &[f64],
        resid: &[f64],
        lambda: f64,
        c: &mut [f64],
    ) -> bool {
        match self.engine.kkt_sweep(self.loss, &self.design, y, eta, lambda) {
            Ok(Some((c_backend, _resid_backend))) => {
                debug_assert_eq!(c_backend.len(), c.len());
                if self.engine.is_exact() {
                    // Exact f64 backend: nothing to re-verify.
                    c.copy_from_slice(&c_backend);
                    return true;
                }
                let lo = lambda * (1.0 - self.recheck_band);
                let hi = lambda * (1.0 + self.recheck_band);
                for (j, cv) in c_backend.into_iter().enumerate() {
                    let a = cv.abs();
                    c[j] = if a >= lo && a <= hi {
                        // Reduced precision can't be trusted at the
                        // threshold: recompute in f64.
                        native.col_dot(j, resid)
                    } else {
                        cv
                    };
                }
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DesignMatrix, SyntheticSpec};

    fn dense_problem(n: usize, p: usize) -> (crate::linalg::DenseMatrix, Vec<f64>) {
        let data = SyntheticSpec::new(n, p, 3).rho(0.2).seed(11).generate();
        let dense = match data.design {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!(),
        };
        (dense, data.response)
    }

    #[test]
    fn native_engine_reports_backend() {
        let e = RuntimeEngine::native();
        assert_eq!(e.backend_name(), "native");
        assert!(e.num_ops() > 0);
    }

    #[test]
    fn native_correlation_matches_direct() {
        let (dense, y) = dense_problem(30, 12);
        let e = RuntimeEngine::native();
        let reg = e.register_design(dense.data(), 30, 12).unwrap();
        let c = e.correlation(&reg, &y).unwrap().expect("native kernel");
        for j in 0..12 {
            assert!((c[j] - dense.col_dot(j, &y)).abs() < 1e-12, "col {j}");
        }
    }

    #[test]
    fn native_supports_all_shapes_except_poisson() {
        let e = RuntimeEngine::native();
        assert!(e.supports_sweep(Loss::Gaussian, 123, 456));
        assert!(e.supports_sweep(Loss::Logistic, 7, 9));
        assert!(!e.supports_sweep(Loss::Poisson, 200, 2_000));
    }

    #[test]
    fn engine_sweep_binds_and_sweeps() {
        let (dense, y) = dense_problem(40, 15);
        let e = RuntimeEngine::native();
        let sweep = EngineSweep::new(&e, &dense, Loss::Gaussian)
            .unwrap()
            .expect("native always binds");
        let eta = vec![0.0; 40];
        let resid = y.clone();
        let mut c = vec![0.0; 15];
        assert!(sweep.full_sweep(&dense, &y, &eta, &resid, 0.5, &mut c));
        for j in 0..15 {
            assert!((c[j] - dense.col_dot(j, &y)).abs() < 1e-12);
        }
    }

    #[test]
    fn poisson_binding_is_none() {
        let (dense, _) = dense_problem(20, 8);
        let e = RuntimeEngine::native();
        assert!(EngineSweep::new(&e, &dense, Loss::Poisson).unwrap().is_none());
    }

    #[test]
    fn manifest_missing_is_error() {
        // Without `pjrt`: feature-gate error. With `pjrt`: manifest
        // read failure. Either way, a clean Err — never a panic.
        let err = RuntimeEngine::load_dir(Path::new("/nonexistent-dir-xyz"));
        assert!(err.is_err());
    }
}
