"""Layer-1 correctness: Pallas kernels vs. the pure-jnp oracles.

This is the core correctness signal for the compiled hot path: the rust
runtime executes exactly what these kernels lower to, so kernel == ref
(to float tolerance) across shapes and dtypes is what licenses the AOT
substitution. Hypothesis drives the shape/dtype sweep.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (advisory oracle suite)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (advisory oracle suite)")

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gram_block, xt_r
from compile.kernels.ref import gram_block_ref, lasso_kkt_ref, xt_r_ref


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- xt_r


@pytest.mark.parametrize(
    "p,n,tp,tn",
    [
        (8, 8, 256, 256),
        (64, 32, 16, 16),
        (100, 40, 256, 256),  # non-power-of-two dims
        (256, 128, 32, 64),
        (17, 13, 4, 4),  # awkward primes → tile fallback
        (1, 5, 256, 256),  # degenerate single predictor
    ],
)
def test_xt_r_matches_ref_shapes(p, n, tp, tn):
    rng = np.random.default_rng(p * 1000 + n)
    xt = rand(rng, p, n)
    r = rand(rng, n, 1)
    got = xt_r(xt, r, tp=tp, tn=tn)
    want = xt_r_ref(xt, r)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=96),
    tp=st.sampled_from([4, 16, 256]),
    tn=st.sampled_from([4, 16, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_xt_r_hypothesis_sweep(p, n, tp, tn, seed):
    rng = np.random.default_rng(seed)
    xt = rand(rng, p, n)
    r = rand(rng, n, 1)
    got = xt_r(xt, r, tp=tp, tn=tn)
    want = xt_r_ref(xt, r)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_xt_r_dtypes(dtype):
    rng = np.random.default_rng(7)
    xt = rand(rng, 32, 24, dtype=dtype)
    r = rand(rng, 24, 1, dtype=dtype)
    got = xt_r(xt, r)
    assert got.dtype == xt.dtype
    np.testing.assert_allclose(got, xt_r_ref(xt, r), rtol=1e-5)


def test_xt_r_zero_residual_gives_zero():
    rng = np.random.default_rng(3)
    xt = rand(rng, 16, 8)
    r = jnp.zeros((8, 1), dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(xt_r(xt, r)), np.zeros((16, 1)))


def test_xt_r_accumulation_across_n_tiles():
    # Force many n-tiles so the @pl.when(i==0) init + accumulate path is
    # exercised; values chosen so partial sums cancel.
    p, n = 4, 64
    xt = jnp.ones((p, n), dtype=jnp.float32)
    r = jnp.asarray(
        np.concatenate([np.ones(32), -np.ones(32)])[:, None], dtype=jnp.float32
    )
    got = xt_r(xt, r, tp=4, tn=8)
    np.testing.assert_allclose(got, np.zeros((p, 1)), atol=1e-6)


# ---------------------------------------------------------- gram_block


@pytest.mark.parametrize(
    "e,d,n,tn",
    [
        (4, 4, 16, 512),
        (8, 3, 100, 16),  # uneven n vs tile target
        (1, 1, 7, 4),
        (32, 16, 256, 64),
    ],
)
def test_gram_block_matches_ref(e, d, n, tn):
    rng = np.random.default_rng(e * 100 + d * 10 + n)
    xe = rand(rng, e, n)
    xd = rand(rng, d, n)
    w = jnp.asarray(rng.uniform(0.05, 1.0, (n, 1)), dtype=np.float32)
    got = gram_block(xe, w, xd, tn=tn)
    want = gram_block_ref(xe, w, xd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=24),
    d=st.integers(min_value=1, max_value=24),
    n=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_block_hypothesis_sweep(e, d, n, seed):
    rng = np.random.default_rng(seed)
    xe = rand(rng, e, n)
    xd = rand(rng, d, n)
    w = jnp.asarray(rng.uniform(0.0, 1.0, (n, 1)), dtype=np.float32)
    got = gram_block(xe, w, xd, tn=16)
    want = gram_block_ref(xe, w, xd)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_gram_block_unit_weights_is_plain_gram():
    rng = np.random.default_rng(11)
    xe = rand(rng, 6, 40)
    w = jnp.ones((40, 1), dtype=jnp.float32)
    got = gram_block(xe, w, xe)
    np.testing.assert_allclose(got, xe @ xe.T, rtol=1e-5, atol=1e-5)
    # symmetry of the self-panel
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-6)


def test_gram_block_upper_bound_weights():
    # Logistic upper bound w = 1/4 (§3.3.3): panel = Gram/4.
    rng = np.random.default_rng(13)
    xe = rand(rng, 5, 32)
    xd = rand(rng, 4, 32)
    w = jnp.full((32, 1), 0.25, dtype=jnp.float32)
    got = gram_block(xe, w, xd)
    np.testing.assert_allclose(got, (xe @ xd.T) / 4.0, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- fused


def test_lasso_kkt_ref_consistency():
    # The fused L2 graph must agree with its pieces.
    from compile import model

    rng = np.random.default_rng(5)
    xt = rand(rng, 20, 12)
    y = rand(rng, 12, 1)
    eta = rand(rng, 12, 1)
    lam = jnp.float32(0.5)
    c, resid, viol = model.lasso_kkt(xt, y, eta, lam)
    c2, r2, v2 = lasso_kkt_ref(xt, y, eta, lam)
    np.testing.assert_allclose(c, c2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(resid, r2, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(viol), np.asarray(v2))


def test_logistic_kkt_residual_in_range():
    from compile import model

    rng = np.random.default_rng(6)
    xt = rand(rng, 10, 30)
    y = jnp.asarray(rng.integers(0, 2, (30, 1)), dtype=np.float32)
    eta = rand(rng, 30, 1)
    _, resid, _ = model.logistic_kkt(xt, y, eta, jnp.float32(0.1))
    assert np.all(np.abs(np.asarray(resid)) <= 1.0)


def test_vmem_estimates_under_budget():
    # The DESIGN.md §Perf claim: default tiles fit comfortably in VMEM.
    from compile.kernels.gram_block import vmem_bytes as gram_vmem
    from compile.kernels.xt_r import vmem_bytes as xtr_vmem

    assert xtr_vmem(256, 256) < 4 * 2**20
    assert gram_vmem(128, 128, 512) < 4 * 2**20
