//! Integration: the AOT → PJRT → solve-path bridge, end to end.
//!
//! Requires `make artifacts` (skips politely otherwise, so `cargo test`
//! stays green on a fresh checkout; `make test` always builds artifacts
//! first).

use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::linalg::Design;
use hessian_screening::loss::Loss;
use hessian_screening::path::PathFitter;
use hessian_screening::runtime::{EngineSweep, RuntimeEngine};
use hessian_screening::screening::ScreeningKind;

fn engine() -> Option<RuntimeEngine> {
    // tests run from the package root
    match RuntimeEngine::load_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime integration test: {err}");
            None
        }
    }
}

#[test]
fn xt_r_artifact_matches_native_within_f32() {
    let Some(engine) = engine() else { return };
    let (n, p) = (200, 2_000);
    let data = SyntheticSpec::new(n, p, 10).rho(0.3).seed(3).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    let r = &data.response;
    let c = engine.correlation(&reg, r).unwrap().expect("artifact");
    assert_eq!(c.len(), p);
    let scale: f64 = r.iter().map(|v| v * v).sum::<f64>().sqrt() * (n as f64).sqrt();
    for j in 0..p {
        let native = dense.col_dot(j, r);
        assert!(
            (c[j] - native).abs() < 1e-4 * scale.max(1.0),
            "col {j}: {} vs {}",
            c[j],
            native
        );
    }
}

#[test]
fn kkt_sweep_artifact_gaussian_and_logistic() {
    let Some(engine) = engine() else { return };
    let (n, p) = (200, 2_000);
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 10)
            .rho(0.2)
            .loss(loss)
            .seed(4)
            .generate();
        let dense = match &data.design {
            DesignMatrix::Dense(m) => m,
            _ => unreachable!(),
        };
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let eta = vec![0.1; n];
        let (c, resid) = engine
            .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
            .unwrap()
            .expect("artifact");
        // native reference
        let mut resid_native = vec![0.0; n];
        loss.pseudo_residual_into(&data.response, &eta, &mut resid_native);
        for i in 0..n {
            assert!((resid[i] - resid_native[i]).abs() < 1e-5, "{loss:?} resid {i}");
        }
        for j in (0..p).step_by(97) {
            let native = dense.col_dot(j, &resid_native);
            assert!(
                (c[j] - native).abs() < 1e-3 * (1.0 + native.abs()),
                "{loss:?} col {j}: {} vs {native}",
                c[j]
            );
        }
    }
}

#[test]
fn gram_block_artifact_matches_native() {
    let Some(engine) = engine() else { return };
    let (e, d, n) = (64, 16, 200);
    let data = SyntheticSpec::new(n, e + d, 5).seed(5).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };
    // Row-major (e, n) panels == concatenated column-major columns.
    let mut xe_t = Vec::with_capacity(e * n);
    for j in 0..e {
        xe_t.extend_from_slice(dense.col(j));
    }
    let mut xd_t = Vec::with_capacity(d * n);
    for j in e..e + d {
        xd_t.extend_from_slice(dense.col(j));
    }
    let w = vec![0.25; n];
    let g = engine
        .gram_block(&xe_t, &w, &xd_t, e, d, n)
        .unwrap()
        .expect("artifact");
    assert_eq!(g.len(), e * d);
    for a in 0..e {
        for b in 0..d {
            let native = 0.25 * dense.gram(a, e + b);
            let got = g[a * d + b]; // row-major (e, d)
            assert!(
                (got - native).abs() < 1e-3 * (1.0 + native.abs()),
                "panel ({a},{b}): {got} vs {native}"
            );
        }
    }
}

#[test]
fn engine_swept_path_equals_native_path() {
    let Some(engine) = engine() else { return };
    let (n, p) = (200, 2_000);
    let data = SyntheticSpec::new(n, p, 10).rho(0.4).seed(6).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };
    let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
        .unwrap()
        .expect("sweep artifact for 200x2000");
    let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
    let native = fitter.fit(&data.design, &data.response);
    let swept = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
    assert_eq!(native.lambdas.len(), swept.lambdas.len());
    let m = native.lambdas.len();
    for k in 0..m {
        let a = native.beta_dense(k, p);
        let b = swept.beta_dense(k, p);
        for j in 0..p {
            assert!(
                (a[j] - b[j]).abs() < 1e-3,
                "step {k} coef {j}: {} vs {}",
                a[j],
                b[j]
            );
        }
    }
}

#[test]
fn unsupported_shapes_fall_back_to_native() {
    let Some(engine) = engine() else { return };
    // 123 x 456 has no artifact: supports_sweep must say no, and
    // EngineSweep::new must return None so the driver stays native.
    assert!(!engine.supports_sweep(Loss::Gaussian, 123, 456));
    let data = SyntheticSpec::new(123, 456, 5).seed(7).generate();
    let dense = match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!(),
    };
    assert!(EngineSweep::new(&engine, dense, Loss::Gaussian)
        .unwrap()
        .is_none());
    // Poisson has no artifact by design (no Lipschitz gradient).
    assert!(!engine.supports_sweep(Loss::Poisson, 200, 2_000));
}
