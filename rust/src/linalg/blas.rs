//! BLAS-level micro-kernels.
//!
//! These are the innermost loops of the whole system: the correlation
//! sweep (Xᵀr) and coordinate-descent updates spend essentially all of
//! their time in `dot` and `axpy`. They are written with manual
//! unrolling and independent accumulators so LLVM auto-vectorizes them
//! to AVX on this target; we verified the vectorization in the perf pass
//! (see EXPERIMENTS.md §Perf).
//!
//! ## Accumulation-order contract
//!
//! Every dot-product kernel in this file produces a **fixed,
//! block-size- and thread-count-independent accumulation order**: the
//! scalar [`dot`] defines the reference sequence (8 independent
//! accumulators over chunks of 8 via `f64::mul_add`, the fixed
//! reduction tree `((a0+a1)+(a2+a3))+((a4+a5)+(a6+a7))`, then a
//! sequential tail), and the register-blocked variants ([`dot_block`],
//! [`dot_panel`], and the weighted twins) replay *exactly that
//! per-column sequence*, merely interleaved across B columns so the
//! shared vector is streamed from memory once per block instead of
//! once per column. Interleaving never mixes values between columns,
//! so blocked output is bitwise identical to the scalar reference at
//! every block width — which is what keeps the repo-wide `==`
//! guarantees (threaded-vs-serial, sharded-vs-unsharded,
//! hxd-vs-resident) intact no matter how the drivers tile the columns.
//! `f64::mul_add` is correctly rounded on every target (hardware FMA
//! or libm fallback), so the contract is also platform-deterministic.

/// xᵀy with 8 independent accumulators.
///
/// Perf note (EXPERIMENTS.md §Perf L3): the 8-lane accumulator array
/// auto-vectorizes to two AVX FMA chains, ~8% faster on the full
/// correlation sweep than the earlier 4-accumulator form (interleaved
/// best-of-15 A/B); a 16-lane variant measured < 5% further and was
/// rejected per the one-change protocol.
///
/// This is the reference accumulation order for the blocked kernels
/// below — see the module docs. Changing the chunking, the reduction
/// tree, or the `mul_add` here is a **breaking change** to every
/// bitwise-equivalence guarantee in the repo.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0.0f64; 8];
    for i in 0..chunks {
        let b = i * 8;
        for (k, a) in acc.iter_mut().enumerate() {
            // SAFETY: b + k <= (chunks-1)*8 + 7 < chunks*8 <= n = x.len(),
            // and y.len() == x.len() (debug_assert above; all callers pass
            // equal-length slices).
            unsafe {
                *a = x.get_unchecked(b + k).mul_add(*y.get_unchecked(b + k), *a);
            }
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s = x[i].mul_add(y[i], s);
    }
    s
}

/// Register-blocked multi-column dot: `B` dots `colsᵀy` computed in one
/// pass over `y`.
///
/// The shared vector `y` is streamed from memory **once** for the whole
/// block (its 8-element chunk stays register-resident across the B
/// columns) instead of once per column — on the memory-bound
/// correlation sweep that is the entire win. Each column `j` owns its
/// private 8-lane accumulator bank, updated in *exactly* the order
/// [`dot`] would use, so `dot_block([c], y)[0] == dot(c, y)` bitwise
/// for every column and every `B` (see the module accumulation-order
/// contract; enforced by the equivalence tests below and in
/// `runtime/native.rs`).
#[inline]
pub fn dot_block<const B: usize>(cols: [&[f64]; B], y: &[f64]) -> [f64; B] {
    let n = y.len();
    for c in &cols {
        debug_assert_eq!(c.len(), n);
    }
    let chunks = n / 8;
    let mut acc = [[0.0f64; 8]; B];
    for i in 0..chunks {
        let b = i * 8;
        let yc = &y[b..b + 8];
        for (aj, col) in acc.iter_mut().zip(cols.iter()) {
            let xc = &col[b..b + 8];
            for k in 0..8 {
                aj[k] = xc[k].mul_add(yc[k], aj[k]);
            }
        }
    }
    let mut out = [0.0f64; B];
    for (j, (o, a)) in out.iter_mut().zip(acc.iter()).enumerate() {
        let mut s = ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]));
        for i in chunks * 8..n {
            s = cols[j][i].mul_add(y[i], s);
        }
        *o = s;
    }
    out
}

/// Blocking width of the panel drivers below. 4 column accumulator
/// banks (32 f64 lanes) plus the streamed chunk fit the 16 AVX
/// registers without spilling; 8 measured no further win.
pub const PANEL_BLOCK: usize = 4;

/// Multi-column dot over a contiguous column-major panel: writes
/// `out[j] = dot(panel[j·n .. (j+1)·n], y)` for every column of the
/// panel, streaming `y` once per [`PANEL_BLOCK`]-wide block and
/// falling back to the scalar [`dot`] for the ragged tail columns.
/// Bitwise identical to the per-column scalar loop at every panel
/// width (the accumulation-order contract).
#[inline]
pub fn dot_panel(panel: &[f64], n: usize, y: &[f64], out: &mut [f64]) {
    if n == 0 {
        for o in out.iter_mut() {
            *o = 0.0;
        }
        return;
    }
    let cols = panel.len() / n;
    debug_assert_eq!(panel.len(), cols * n);
    debug_assert_eq!(out.len(), cols);
    debug_assert_eq!(y.len(), n);
    let mut j = 0;
    while j + PANEL_BLOCK <= cols {
        let r = dot_block::<PANEL_BLOCK>(
            [
                &panel[j * n..(j + 1) * n],
                &panel[(j + 1) * n..(j + 2) * n],
                &panel[(j + 2) * n..(j + 3) * n],
                &panel[(j + 3) * n..(j + 4) * n],
            ],
            y,
        );
        out[j..j + PANEL_BLOCK].copy_from_slice(&r);
        j += PANEL_BLOCK;
    }
    while j < cols {
        out[j] = dot(&panel[j * n..(j + 1) * n], y);
        j += 1;
    }
}

/// y ← y + alpha·x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    let n = x.len();
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        // SAFETY: b + 3 <= (chunks-1)*4 + 3 < chunks*4 <= n = x.len() ==
        // y.len() (debug_assert above).
        unsafe {
            *y.get_unchecked_mut(b) += alpha * x.get_unchecked(b);
            *y.get_unchecked_mut(b + 1) += alpha * x.get_unchecked(b + 1);
            *y.get_unchecked_mut(b + 2) += alpha * x.get_unchecked(b + 2);
            *y.get_unchecked_mut(b + 3) += alpha * x.get_unchecked(b + 3);
        }
    }
    for i in chunks * 4..n {
        y[i] += alpha * x[i];
    }
}

/// Fused dot of one column with two vectors at once: (xᵀa, xᵀb).
/// Saves a full pass over x in the weighted-gram and dual computations.
#[inline]
pub fn dot2(x: &[f64], a: &[f64], b: &[f64]) -> (f64, f64) {
    debug_assert_eq!(x.len(), a.len());
    debug_assert_eq!(x.len(), b.len());
    let n = x.len();
    let (mut s0, mut s1) = (0.0, 0.0);
    for i in 0..n {
        // SAFETY: i < n = x.len(), and a.len() == b.len() == x.len()
        // (debug_asserts above).
        unsafe {
            let xi = *x.get_unchecked(i);
            s0 += xi * a.get_unchecked(i);
            s1 += xi * b.get_unchecked(i);
        }
    }
    (s0, s1)
}

/// Weighted dot Σ wᵢ xᵢ yᵢ.
///
/// Reference accumulation order for [`dot_w_block`]/[`dot_w_panel`]:
/// one sequential accumulator, `(wᵢ·xᵢ)` rounded once then folded in
/// via `mul_add` — the blocked twins must replay exactly this.
#[inline]
pub fn dot_w(x: &[f64], y: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), w.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        // SAFETY: i < x.len(), and y.len() == w.len() == x.len()
        // (debug_asserts above).
        unsafe {
            s = (w.get_unchecked(i) * x.get_unchecked(i)).mul_add(*y.get_unchecked(i), s);
        }
    }
    s
}

/// Register-blocked weighted multi-column dot: `B` weighted dots
/// `dot_w(x, col_j, w)` in one pass over `x` and `w`.
///
/// The streamed vector `x` sits in [`dot_w`]'s **first** slot on
/// purpose: the Gram panel rows compute `dot_w(x_row, col, w)`, and the
/// `wᵢ·xᵢ` product must round once *before* meeting the column (it is
/// not commutative with `wᵢ·colᵢ` at the bit level). Per-column
/// accumulation is exactly [`dot_w`]'s one sequential accumulator, so
/// the result is bitwise identical to the scalar reference at every
/// `B`.
#[inline]
pub fn dot_w_block<const B: usize>(x: &[f64], cols: [&[f64]; B], w: &[f64]) -> [f64; B] {
    let n = x.len();
    debug_assert_eq!(w.len(), n);
    for c in &cols {
        debug_assert_eq!(c.len(), n);
    }
    let mut s = [0.0f64; B];
    for i in 0..n {
        let z = w[i] * x[i];
        for (sj, col) in s.iter_mut().zip(cols.iter()) {
            *sj = z.mul_add(col[i], *sj);
        }
    }
    s
}

/// Weighted twin of [`dot_panel`]: `out[j] = dot_w(x, col_j, w)` over a
/// contiguous column-major panel, streaming `x`/`w` once per
/// [`PANEL_BLOCK`]-wide block. Bitwise identical to the per-column
/// scalar loop (argument orientation: see [`dot_w_block`]).
#[inline]
pub fn dot_w_panel(panel: &[f64], n: usize, x: &[f64], w: &[f64], out: &mut [f64]) {
    if n == 0 {
        for o in out.iter_mut() {
            *o = 0.0;
        }
        return;
    }
    let cols = panel.len() / n;
    debug_assert_eq!(panel.len(), cols * n);
    debug_assert_eq!(out.len(), cols);
    let mut j = 0;
    while j + PANEL_BLOCK <= cols {
        let r = dot_w_block::<PANEL_BLOCK>(
            x,
            [
                &panel[j * n..(j + 1) * n],
                &panel[(j + 1) * n..(j + 2) * n],
                &panel[(j + 2) * n..(j + 3) * n],
                &panel[(j + 3) * n..(j + 4) * n],
            ],
            w,
        );
        out[j..j + PANEL_BLOCK].copy_from_slice(&r);
        j += PANEL_BLOCK;
    }
    while j < cols {
        out[j] = dot_w(x, &panel[j * n..(j + 1) * n], w);
        j += 1;
    }
}

/// ‖x‖₂².
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    dot(x, x)
}

/// ‖x‖₂.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    sq_norm(x).sqrt()
}

/// ‖x‖₁.
#[inline]
pub fn asum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// max |xᵢ|.
#[inline]
pub fn amax(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// y ← x.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// x ← alpha·x.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Soft-thresholding operator S(z, t) = sign(z)·max(|z|−t, 0): the
/// elementary step of ℓ₁ coordinate descent.
#[inline(always)]
pub fn soft_threshold(z: f64, t: f64) -> f64 {
    if z > t {
        z - t
    } else if z < -t {
        z + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(x: &[f64], y: &[f64]) -> f64 {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }

    #[test]
    fn dot_matches_naive_various_lengths() {
        for n in [0, 1, 2, 3, 4, 5, 7, 8, 17, 64, 100, 257] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
            let got = dot(&x, &y);
            let want = naive_dot(&x, &y);
            assert!((got - want).abs() < 1e-10 * (1.0 + want.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0, 1, 3, 4, 9, 33, 128] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 + 0.5).collect();
            let mut y: Vec<f64> = (0..n).map(|i| (i as f64).sqrt()).collect();
            let mut y2 = y.clone();
            axpy(1.75, &x, &mut y);
            for i in 0..n {
                y2[i] += 1.75 * x[i];
            }
            assert_eq!(y, y2, "n={n}");
        }
    }

    #[test]
    fn axpy_zero_alpha_is_noop() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![4.0, 5.0, 6.0];
        axpy(0.0, &x, &mut y);
        assert_eq!(y, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn dot2_consistent_with_dot() {
        let x: Vec<f64> = (0..37).map(|i| (i as f64 * 0.3).sin()).collect();
        let a: Vec<f64> = (0..37).map(|i| (i as f64 * 0.7).cos()).collect();
        let b: Vec<f64> = (0..37).map(|i| i as f64 * 0.01).collect();
        let (da, db) = dot2(&x, &a, &b);
        assert!((da - dot(&x, &a)).abs() < 1e-12);
        assert!((db - dot(&x, &b)).abs() < 1e-12);
    }

    #[test]
    fn weighted_dot() {
        let x = vec![1.0, 2.0, 3.0];
        let y = vec![2.0, 0.5, 1.0];
        let w = vec![0.25, 0.25, 0.5];
        assert!((dot_w(&x, &y, &w) - (0.5 + 0.25 + 1.5)).abs() < 1e-14);
    }

    #[test]
    fn norms_and_amax() {
        let x = vec![3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-14);
        assert!((sq_norm(&x) - 25.0).abs() < 1e-14);
        assert!((asum(&x) - 7.0).abs() < 1e-14);
        assert!((amax(&x) - 4.0).abs() < 1e-14);
        assert_eq!(amax(&[]), 0.0);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    /// B columns of length n with irrational-ish entries so no product
    /// is exactly representable — any accumulation-order drift between
    /// the scalar and blocked kernels shows up as a bit flip.
    fn cols_of(b: usize, n: usize) -> Vec<Vec<f64>> {
        (0..b)
            .map(|j| {
                (0..n)
                    .map(|i| ((i * 7 + j * 13) as f64 * 0.2913).sin() * 1.7)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn dot_block_bit_identical_to_scalar_all_widths() {
        // Ragged lengths around the 8-chunk boundary; every block
        // width the drivers could ever tile with.
        for n in [0, 1, 5, 7, 8, 9, 16, 23, 64, 101] {
            let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.173).cos()).collect();
            let cols = cols_of(8, n);
            macro_rules! check {
                ($b:literal) => {{
                    let refs: [&[f64]; $b] = std::array::from_fn(|j| cols[j].as_slice());
                    let got = dot_block::<$b>(refs, &y);
                    for j in 0..$b {
                        let want = dot(&cols[j], &y);
                        assert_eq!(
                            got[j].to_bits(),
                            want.to_bits(),
                            "B={} j={j} n={n}",
                            $b
                        );
                    }
                }};
            }
            check!(1);
            check!(2);
            check!(4);
            check!(8);
        }
    }

    #[test]
    fn dot_w_block_bit_identical_to_scalar_all_widths() {
        for n in [0, 3, 8, 17, 50] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.31).cos()).collect();
            let w: Vec<f64> = (0..n).map(|i| 0.1 + (i as f64 * 0.07).sin().abs()).collect();
            let cols = cols_of(8, n);
            macro_rules! check {
                ($b:literal) => {{
                    let refs: [&[f64]; $b] = std::array::from_fn(|j| cols[j].as_slice());
                    let got = dot_w_block::<$b>(&x, refs, &w);
                    for j in 0..$b {
                        let want = dot_w(&x, &cols[j], &w);
                        assert_eq!(got[j].to_bits(), want.to_bits(), "B={} j={j} n={n}", $b);
                    }
                }};
            }
            check!(1);
            check!(2);
            check!(4);
            check!(8);
        }
    }

    #[test]
    fn dot_panel_bit_identical_to_per_column_scalar_ragged() {
        // Panel widths straddling the PANEL_BLOCK boundary (ragged
        // tails of 1..B-1 columns) and ragged row counts.
        for n in [1, 7, 9, 33] {
            for cols in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 13] {
                let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.41).sin()).collect();
                let w: Vec<f64> = (0..n).map(|i| 0.2 + (i as f64 * 0.19).cos().abs()).collect();
                let panel: Vec<f64> = (0..cols * n)
                    .map(|i| ((i * 3) as f64 * 0.117).sin() * 2.3)
                    .collect();
                let mut got = vec![0.0; cols];
                dot_panel(&panel, n, &y, &mut got);
                for j in 0..cols {
                    let want = dot(&panel[j * n..(j + 1) * n], &y);
                    assert_eq!(got[j].to_bits(), want.to_bits(), "cols={cols} j={j} n={n}");
                }
                let mut got_w = vec![0.0; cols];
                dot_w_panel(&panel, n, &y, &w, &mut got_w);
                for j in 0..cols {
                    let want = dot_w(&y, &panel[j * n..(j + 1) * n], &w);
                    assert_eq!(got_w[j].to_bits(), want.to_bits(), "w cols={cols} j={j} n={n}");
                }
            }
        }
    }

    #[test]
    fn zero_length_panels_write_zeros() {
        let mut out = vec![1.0; 3];
        dot_panel(&[], 0, &[], &mut out);
        assert_eq!(out, vec![0.0; 3]);
        let mut out = vec![1.0; 2];
        dot_w_panel(&[], 0, &[], &[], &mut out);
        assert_eq!(out, vec![0.0; 2]);
    }

    #[test]
    fn scal_and_copy() {
        let mut x = vec![1.0, -2.0, 4.0];
        scal(0.5, &mut x);
        assert_eq!(x, vec![0.5, -1.0, 2.0]);
        let mut y = vec![0.0; 3];
        copy(&x, &mut y);
        assert_eq!(x, y);
    }
}
