//! Quickstart: simulate a lasso problem, fit a full regularization path
//! with the Hessian Screening Rule, and inspect the result.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 5-minute tour of the public API: synthetic data
//! generation (§4.1 of the paper), `PathFitter`, and the per-step
//! statistics that the benchmark harness aggregates.

use hessian_screening::metrics::Table;
use hessian_screening::prelude::*;

fn main() {
    // n=200 observations, p=2000 predictors, 10 true signals,
    // pairwise correlation 0.4, SNR 2 — a small version of the paper's
    // high-dimensional scenario.
    let data = SyntheticSpec::new(200, 2_000, 10)
        .rho(0.4)
        .snr(2.0)
        .seed(42)
        .generate();

    // Compare the paper's method with the working-set baseline.
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
        let fit = PathFitter::new(Loss::Gaussian, kind).fit(&data.design, &data.response);
        println!(
            "method={:<8} steps={:<3} total CD passes={:<5} mean screened={:<8.1} time={:.3}s",
            kind.name(),
            fit.lambdas.len(),
            fit.total_passes(),
            fit.mean_screened(),
            fit.total_time
        );
    }

    // A closer look at the Hessian fit.
    let fit = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian)
        .fit(&data.design, &data.response);
    let mut table = Table::new(&["step", "lambda", "active", "screened", "passes", "dev ratio"]);
    for k in (0..fit.lambdas.len()).step_by(10) {
        let s = &fit.steps[k];
        table.row(vec![
            format!("{k}"),
            format!("{:.4}", fit.lambdas[k]),
            format!("{}", s.active),
            format!("{}", s.screened),
            format!("{}", s.passes),
            format!("{:.3}", s.dev_ratio),
        ]);
    }
    println!("\n{}", table.render());

    // Recover the support at the end of the path and compare with the
    // planted signal.
    let truth = data.beta_true.as_ref().unwrap();
    let last = fit.betas.last().unwrap();
    let found: Vec<usize> = last.iter().map(|&(j, _)| j).collect();
    let true_support: Vec<usize> = truth
        .iter()
        .enumerate()
        .filter(|(_, b)| **b != 0.0)
        .map(|(j, _)| j)
        .collect();
    let recovered = true_support.iter().filter(|j| found.contains(j)).count();
    println!(
        "support recovery: {recovered}/{} planted signals in the final active set ({} active)",
        true_support.len(),
        found.len()
    );
}
