//! Compressed-sparse-column designs.
//!
//! The paper's text-derived data sets (e2006-tfidf, e2006-log1p, news20,
//! rcv1) are sparse with densities between 3·10⁻⁴ and 8·10⁻³; our
//! analogues use this CSC type. CSC is the natural layout because every
//! solver primitive is column-oriented (see `linalg::mod`).
//!
//! Standardization of sparse designs: columns are *scaled* but not
//! centered (centering would densify). The data layer accounts for this
//! (see `data::standardize`), matching common sparse-GLM practice.

use super::Design;

#[derive(Clone, Debug)]
pub struct CscMatrix {
    nrows: usize,
    ncols: usize,
    /// Column pointer array, length ncols+1.
    colptr: Vec<usize>,
    /// Row indices, length nnz, sorted within each column.
    rowind: Vec<u32>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from triplets (row, col, value). Duplicates are summed.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Self {
        let mut per_col: Vec<Vec<(u32, f64)>> = vec![Vec::new(); ncols];
        for &(i, j, v) in triplets {
            assert!(i < nrows && j < ncols, "triplet out of range");
            per_col[j].push((i as u32, v));
        }
        let mut colptr = Vec::with_capacity(ncols + 1);
        let mut rowind = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for col in per_col.iter_mut() {
            col.sort_unstable_by_key(|&(i, _)| i);
            let mut k = 0;
            while k < col.len() {
                let (i, mut v) = col[k];
                let mut k2 = k + 1;
                while k2 < col.len() && col[k2].0 == i {
                    v += col[k2].1;
                    k2 += 1;
                }
                rowind.push(i);
                values.push(v);
                k = k2;
            }
            colptr.push(rowind.len());
        }
        Self {
            nrows,
            ncols,
            colptr,
            rowind,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (row indices, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let a = self.colptr[j];
        let b = self.colptr[j + 1];
        (&self.rowind[a..b], &self.values[a..b])
    }

    /// Scale column j in place by `alpha`.
    pub fn scale_col(&mut self, j: usize, alpha: f64) {
        let a = self.colptr[j];
        let b = self.colptr[j + 1];
        for v in &mut self.values[a..b] {
            *v *= alpha;
        }
    }

    /// Column mean (over all n rows, zeros included).
    pub fn col_mean(&self, j: usize) -> f64 {
        let (_, vals) = self.col(j);
        vals.iter().sum::<f64>() / self.nrows as f64
    }

    /// Densify (tests / small problems only).
    pub fn to_dense(&self) -> super::DenseMatrix {
        let mut d = super::DenseMatrix::zeros(self.nrows, self.ncols);
        for j in 0..self.ncols {
            let (ri, vals) = self.col(j);
            for (&i, &v) in ri.iter().zip(vals) {
                *d.at_mut(i as usize, j) = v;
            }
        }
        d
    }
}

impl Design for CscMatrix {
    fn nrows(&self) -> usize {
        self.nrows
    }

    fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (ri, vals) = self.col(j);
        let mut s = 0.0;
        for (&i, &x) in ri.iter().zip(vals) {
            // SAFETY: `from_triplets` (the only constructor) asserts every
            // row index < nrows, and the Design contract gives
            // v.len() == nrows, so i as usize < v.len().
            s += x * unsafe { *v.get_unchecked(i as usize) };
        }
        s
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, v: &mut [f64]) {
        if alpha == 0.0 {
            return;
        }
        let (ri, vals) = self.col(j);
        for (&i, &x) in ri.iter().zip(vals) {
            // SAFETY: row indices < nrows by the `from_triplets` CSC
            // invariant and v.len() == nrows (Design contract).
            unsafe {
                *v.get_unchecked_mut(i as usize) += alpha * x;
            }
        }
    }

    #[inline]
    fn col_sq_norm(&self, j: usize) -> f64 {
        let (_, vals) = self.col(j);
        vals.iter().map(|v| v * v).sum()
    }

    fn gram(&self, i: usize, j: usize) -> f64 {
        // Sorted-merge of the two sparse columns.
        let (ri, vi) = self.col(i);
        let (rj, vj) = self.col(j);
        let (mut a, mut b, mut s) = (0usize, 0usize, 0.0);
        while a < ri.len() && b < rj.len() {
            match ri[a].cmp(&rj[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += vi[a] * vj[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    fn gram_weighted(&self, i: usize, j: usize, w: Option<&[f64]>) -> f64 {
        match w {
            None => self.gram(i, j),
            Some(w) => {
                let (ri, vi) = self.col(i);
                let (rj, vj) = self.col(j);
                let (mut a, mut b, mut s) = (0usize, 0usize, 0.0);
                while a < ri.len() && b < rj.len() {
                    match ri[a].cmp(&rj[b]) {
                        std::cmp::Ordering::Less => a += 1,
                        std::cmp::Ordering::Greater => b += 1,
                        std::cmp::Ordering::Equal => {
                            s += w[ri[a] as usize] * vi[a] * vj[b];
                            a += 1;
                            b += 1;
                        }
                    }
                }
                s
            }
        }
    }

    fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn construction_and_nnz() {
        let m = sample();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.ncols(), 3);
        let (ri, v) = m.col(0);
        assert_eq!(ri, &[0, 2]);
        assert_eq!(v, &[1.0, 4.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = CscMatrix::from_triplets(2, 1, &[(0, 0, 1.0), (0, 0, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.col(0).1, &[3.5]);
    }

    #[test]
    fn col_dot_axpy_against_dense() {
        let m = sample();
        let d = m.to_dense();
        let v = vec![1.0, -2.0, 0.5];
        for j in 0..3 {
            assert!((m.col_dot(j, &v) - d.col_dot(j, &v)).abs() < 1e-14);
        }
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        m.col_axpy(2, 1.5, &mut a);
        d.col_axpy(2, 1.5, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn gram_merge_matches_dense() {
        let m = sample();
        let d = m.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (m.gram(i, j) - d.gram(i, j)).abs() < 1e-14,
                    "({i},{j})"
                );
            }
        }
        let w = vec![0.5, 2.0, 1.0];
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (m.gram_weighted(i, j, Some(&w)) - d.gram_weighted(i, j, Some(&w))).abs()
                        < 1e-14
                );
            }
        }
    }

    #[test]
    fn density_and_scaling() {
        let mut m = sample();
        assert!((m.density() - 5.0 / 9.0).abs() < 1e-14);
        m.scale_col(0, 2.0);
        assert_eq!(m.col(0).1, &[2.0, 8.0]);
        assert!((m.col_mean(0) - 10.0 / 3.0).abs() < 1e-14);
    }

    #[test]
    fn t_gemv_default_impl() {
        let m = sample();
        let d = m.to_dense();
        let v = vec![1.0, 2.0, 3.0];
        let mut o1 = vec![0.0; 3];
        let mut o2 = vec![0.0; 3];
        m.t_gemv(&v, &mut o1);
        d.t_gemv(&v, &mut o2);
        assert_eq!(o1, o2);
    }
}
