//! Table 1 / Table 4: time to fit a full path on the real-data
//! analogues (DESIGN.md §3 documents the substitution). All four main
//! methods on each of the twelve data sets, with 95% CIs (Table 4).

use super::*;
use crate::data::dataset_catalog;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    run_subset(cfg, None)
}

/// Run on a named subset (CLI: `hx exp tab1 --datasets colon-cancer,...`).
pub fn run_subset(cfg: &ExpConfig, only: Option<&[String]>) -> Result<(), String> {
    let mut catalog = dataset_catalog();
    if let Some(names) = only {
        catalog.retain(|d| names.iter().any(|n| n.eq_ignore_ascii_case(d.name)));
        if catalog.is_empty() {
            return Err("no matching datasets".into());
        }
    } else if !cfg.full {
        // Quick preset: shrink the big analogues further.
        for d in catalog.iter_mut() {
            if d.n * d.p > 20_000_000 || d.density.is_some() {
                d.n = (d.n / 4).max(50);
                d.p = (d.p / 4).max(20);
            }
        }
    }

    struct Cell {
        ds: usize,
        kind: ScreeningKind,
        rep: u64,
    }
    let mut cells = Vec::new();
    for (ds, spec) in catalog.iter().enumerate() {
        // Paper: 20 reps small sets, 3 reps large.
        let reps = if spec.n * spec.p > 5_000_000 {
            cfg.reps.min(3)
        } else {
            cfg.reps
        };
        for kind in main_methods() {
            for rep in 0..reps as u64 {
                cells.push(Cell { ds, kind, rep });
            }
        }
    }
    let catalog_ref = &catalog;
    let results = cfg
        .coordinator()
        .run_with_progress("tab1", cells, |_, c| {
            let data = catalog_ref[c.ds].generate(c.rep);
            let (fit, secs) = fit_timed(&data, c.kind, &paper_settings());
            (c.ds, c.kind, secs, fit.steps.len())
        });

    let mut table = Table::new(&[
        "Dataset", "n", "p", "Density", "Loss", "Method", "Time (s)", "CI lo", "CI hi",
    ]);
    for (ds, spec) in catalog.iter().enumerate() {
        for kind in main_methods() {
            let times: Vec<f64> = results
                .iter()
                .filter(|(d, k, _, _)| *d == ds && *k == kind)
                .map(|(_, _, t, _)| *t)
                .collect();
            let s = Summary::of(&times);
            table.row(vec![
                spec.name.into(),
                format!("{}", spec.n),
                format!("{}", spec.p),
                format!("{:.2}", spec.density.unwrap_or(1.0)),
                format!("{:?}", spec.loss),
                kind.name().into(),
                format!("{}", sig_figs(s.mean, 3)),
                format!("{}", sig_figs(s.lo(), 3)),
                format!("{}", sig_figs(s.hi(), 3)),
            ]);
        }
    }
    println!("\nTable 1 / Table 4 — real-data analogues, full-path time");
    println!("{}", table.render());
    write_csv(cfg, "tab1_real_data", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset_by_name;

    #[test]
    fn colon_cancer_analogue_hessian_wins() {
        // The paper's colon-cancer row: Hessian ~2.5x faster than
        // working+. Require a win on the analogue (looser: ≥ parity).
        let spec = dataset_by_name("colon-cancer").unwrap();
        let data = spec.generate(0);
        let mut t_h = 0.0;
        let mut t_w = 0.0;
        for _ in 0..3 {
            t_h += fit_timed(&data, ScreeningKind::Hessian, &paper_settings()).1;
            t_w += fit_timed(&data, ScreeningKind::Working, &paper_settings()).1;
        }
        assert!(t_h <= t_w * 1.2, "hessian {t_h:.3} vs working {t_w:.3}");
    }

    #[test]
    fn subset_selection_errors_on_unknown() {
        let cfg = ExpConfig {
            reps: 1,
            ..Default::default()
        };
        let err = run_subset(&cfg, Some(&["nope".to_string()]));
        assert!(err.is_err());
    }
}
