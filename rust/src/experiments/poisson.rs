//! Appendix F.9 (Figure 11): ℓ₁-regularized Poisson regression.
//! ρ ∈ {0, 0.15, 0.3} (the paper's reduced range — CD struggles at
//! higher correlation for Poisson); Hessian vs working. Gap-Safe-based
//! methods (Blitz/Celer) are excluded because the Poisson gradient is
//! not Lipschitz (the augmentation is likewise auto-disabled by
//! `Loss::supports_gap_safe`).

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let (n, p, s) = cfg.high_dim();
    let methods = [ScreeningKind::Hessian, ScreeningKind::Working];
    struct Cell {
        kind: ScreeningKind,
        rho: f64,
        rep: u64,
    }
    let mut cells = Vec::new();
    for &kind in &methods {
        for &rho in &[0.0, 0.15, 0.3] {
            for rep in 0..cfg.reps as u64 {
                cells.push(Cell { kind, rho, rep });
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig11", cells, |_, c| {
        let data = simulate(n, p, s, c.rho, 2.0, Loss::Poisson, cfg.cell_seed(7_000, c.rep));
        let (_, secs) = fit_timed(&data, c.kind, &paper_settings());
        (c.kind, c.rho, secs)
    });

    let mut table = Table::new(&["Method", "rho", "Time (s)", "CI lo", "CI hi", "Relative"]);
    for &rho in &[0.0, 0.15, 0.3] {
        let min_mean = methods
            .iter()
            .map(|&kind| {
                let times: Vec<f64> = results
                    .iter()
                    .filter(|(k, r, _)| *k == kind && *r == rho)
                    .map(|(_, _, t)| *t)
                    .collect();
                Summary::of(&times).mean
            })
            .fold(f64::INFINITY, f64::min);
        for &kind in &methods {
            let times: Vec<f64> = results
                .iter()
                .filter(|(k, r, _)| *k == kind && *r == rho)
                .map(|(_, _, t)| *t)
                .collect();
            let sm = Summary::of(&times);
            table.row(vec![
                kind.name().into(),
                format!("{rho}"),
                format!("{}", sig_figs(sm.mean, 3)),
                format!("{}", sig_figs(sm.lo(), 3)),
                format!("{}", sig_figs(sm.hi(), 3)),
                format!("{}", sig_figs(sm.mean / min_mean, 3)),
            ]);
        }
    }
    println!("\nFigure 11 — ℓ₁-regularized Poisson regression");
    println!("{}", table.render());
    write_csv(cfg, "fig11_poisson", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_paths_agree_between_methods() {
        let data = simulate(80, 300, 5, 0.15, 2.0, Loss::Poisson, 13);
        let mut settings = paper_settings();
        settings.cd.eps = 1e-7;
        let (h, _) = fit_timed(&data, ScreeningKind::Hessian, &settings);
        let (w, _) = fit_timed(&data, ScreeningKind::Working, &settings);
        let m = h.lambdas.len().min(w.lambdas.len());
        assert!(m > 3);
        for k in 0..m {
            let a = h.beta_dense(k, 300);
            let b = w.beta_dense(k, 300);
            for j in 0..300 {
                assert!((a[j] - b[j]).abs() < 5e-3, "step {k} coef {j}");
            }
        }
    }

    #[test]
    fn gap_safe_disabled_for_poisson() {
        // supports_gap_safe drives both the augmentation and the rule
        // availability; this is the F.9 footnote as a test.
        assert!(!Loss::Poisson.supports_gap_safe());
    }
}
