//! Appendix F.3 (Figure 6): benefit of augmenting the heuristic methods
//! with Gap-Safe screening in the KKT loop (§3.3.4). Hessian and
//! working strategies, with and without the augmentation, across ρ.

use super::*;
use crate::metrics::{sig_figs, Summary, Table};

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let (n, p, s) = cfg.appendix_dim();
    struct Cell {
        kind: ScreeningKind,
        aug: bool,
        rho: f64,
        rep: u64,
    }
    let mut cells = Vec::new();
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
        for aug in [true, false] {
            for &rho in &[0.0, 0.4, 0.8] {
                for rep in 0..cfg.reps as u64 {
                    cells.push(Cell {
                        kind,
                        aug,
                        rho,
                        rep,
                    });
                }
            }
        }
    }
    let results = cfg.coordinator().run_with_progress("fig6", cells, |_, c| {
        let data = simulate(n, p, s, c.rho, 2.0, Loss::Gaussian, cfg.cell_seed(3_000, c.rep));
        let mut settings = paper_settings();
        settings.use_gap_safe_aug = c.aug;
        let (fit, secs) = fit_timed(&data, c.kind, &settings);
        (c.kind, c.aug, c.rho, secs, fit.steps.iter().map(|s| s.full_sweeps).sum::<usize>())
    });

    let mut table = Table::new(&["Method", "Gap Safe", "rho", "Time (s)", "CI half", "Full sweeps"]);
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
        for aug in [true, false] {
            for &rho in &[0.0, 0.4, 0.8] {
                let rows: Vec<_> = results
                    .iter()
                    .filter(|(k, a, r, _, _)| *k == kind && *a == aug && *r == rho)
                    .collect();
                let sm = Summary::of(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
                let sweeps = rows.iter().map(|r| r.4 as f64).sum::<f64>() / rows.len().max(1) as f64;
                table.row(vec![
                    kind.name().into(),
                    if aug { "on" } else { "off" }.into(),
                    format!("{rho}"),
                    format!("{}", sig_figs(sm.mean, 3)),
                    format!("{}", sig_figs(sm.ci_half, 2)),
                    format!("{}", sig_figs(sweeps, 3)),
                ]);
            }
        }
    }
    println!("\nFigure 6 — Gap-Safe augmentation of the KKT loop");
    println!("{}", table.render());
    write_csv(cfg, "fig6_gap_safe", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn augmentation_does_not_change_solutions() {
        let data = simulate(50, 500, 5, 0.8, 2.0, Loss::Gaussian, 6);
        let mut on = paper_settings();
        on.cd.eps = 1e-7;
        let mut off = on.clone();
        off.use_gap_safe_aug = false;
        let (a, _) = fit_timed(&data, ScreeningKind::Working, &on);
        let (b, _) = fit_timed(&data, ScreeningKind::Working, &off);
        let m = a.lambdas.len().min(b.lambdas.len());
        for k in 0..m {
            let ba = a.beta_dense(k, 500);
            let bb = b.beta_dense(k, 500);
            for j in 0..500 {
                assert!((ba[j] - bb[j]).abs() < 1e-3, "step {k} coef {j}");
            }
        }
    }
}
