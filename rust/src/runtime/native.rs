//! The pure-Rust compute backend: exact f64 kernels on top of
//! [`crate::linalg`]. This is the reference implementation of the
//! [`Backend`] surface — always available, no artifacts, no FFI — and
//! the baseline every accelerated backend is cross-checked against
//! (`rust/tests/runtime_roundtrip.rs`).
//!
//! Parallelism: the sweep and panel kernels are chunked
//! column-parallel over `std::thread::scope` (zero dependencies), and
//! within each chunk the columns run through the register-blocked
//! panel kernels (`blas::dot_panel` / `blas::dot_w_panel`), which
//! stream the shared vector once per `blas::PANEL_BLOCK` columns.
//! Every output entry is produced by *exactly* the scalar kernel's
//! accumulation sequence regardless of thread count, chunk boundary,
//! or block width (the `linalg::blas` accumulation-order contract), so
//! results are **bit-identical** to the serial scalar loop — threading
//! and blocking are pure wall-clock knobs, never numerics knobs.
//!
//! Allocation: the `_into` overrides write into caller-owned buffers,
//! so the steady-state path loop (which calls them through
//! [`super::RuntimeEngine`]) performs no per-sweep heap allocation
//! once the buffers have grown to size. The allocating [`Backend`]
//! methods are thin wrappers retained for one-shot callers and tests.

#![forbid(unsafe_code)]

use super::{Backend, DesignRepr, KktBatch, RegisteredDesign};
use crate::error::Result;
use crate::linalg::blas;
use crate::loss::Loss;

/// Minimum multiply-add count before spawning threads pays for itself
/// (scope + spawn overhead is on the order of tens of microseconds).
const PAR_FLOP_CUTOFF: usize = 1 << 18;

/// ⌈a/b⌉ (usize::div_ceil needs Rust 1.73; MSRV is 1.70).
fn div_ceil(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

/// The pure-Rust backend. `threads` controls chunked column-parallel
/// execution of the sweep/panel kernels; 1 = serial.
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self { threads: 1 }
    }
}

/// The op kinds the native backend serves: xt_r, the fused KKT sweep
/// (Gaussian + logistic), the row-masked fold sweep, the batched
/// look-ahead sweep, and the weighted Gram panel.
const NATIVE_OPS: usize = 5;

impl NativeBackend {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    fn column(data: &[f64], n: usize, j: usize) -> &[f64] {
        &data[j * n..(j + 1) * n]
    }

    fn design_data(design: &RegisteredDesign) -> Result<&[f64]> {
        match &design.repr {
            DesignRepr::Native(data) => Ok(data),
            _ => Err(crate::err!(
                "design was registered with a different backend"
            )),
        }
    }

    /// Worker count for `items` outputs of `flops_per_item` work each.
    fn pool_size(&self, items: usize, flops_per_item: usize) -> usize {
        if self.threads <= 1 || items.saturating_mul(flops_per_item) < PAR_FLOP_CUTOFF {
            1
        } else {
            self.threads.min(items.max(1))
        }
    }

    /// Blocked column sweep: `out[j] = dot(col_j, r)` for every column
    /// of the col-major `data`, contiguous column chunks per thread,
    /// each chunk running through the register-blocked
    /// `blas::dot_panel`. Every entry equals the scalar `blas::dot`
    /// bitwise (accumulation-order contract), so neither the chunk
    /// boundaries nor the block width can change a single bit.
    fn par_sweep(&self, data: &[f64], n: usize, r: &[f64], out: &mut [f64]) {
        let t = self.pool_size(out.len(), n);
        if t <= 1 {
            blas::dot_panel(data, n, r, out);
            return;
        }
        let chunk = div_ceil(out.len(), t);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (ci, co) in out.chunks_mut(chunk).enumerate() {
                let lo = ci * chunk;
                let panel = &data[lo * n..(lo + co.len()) * n];
                handles.push(s.spawn(move || blas::dot_panel(panel, n, r, co)));
            }
            for h in handles {
                h.join().expect("sweep worker panicked");
            }
        });
    }

    /// Row-blocked variant for row-major (rows, row_len) panels:
    /// `f(a, row)` fills row a. Bit-identical to the serial loop.
    fn par_map_rows(
        &self,
        rows: usize,
        row_len: usize,
        out: &mut [f64],
        flops_per_row: usize,
        f: impl Fn(usize, &mut [f64]) + Sync,
    ) {
        debug_assert_eq!(out.len(), rows * row_len);
        let t = self.pool_size(rows, flops_per_row);
        if t <= 1 {
            for (a, ro) in out.chunks_mut(row_len.max(1)).enumerate() {
                f(a, ro);
            }
            return;
        }
        let rows_per = div_ceil(rows, t);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (ci, co) in out.chunks_mut(rows_per * row_len).enumerate() {
                let f = &f;
                handles.push(s.spawn(move || {
                    for (i, ro) in co.chunks_mut(row_len).enumerate() {
                        f(ci * rows_per + i, ro);
                    }
                }));
            }
            for h in handles {
                h.join().expect("panel worker panicked");
            }
        });
    }

    /// Row-masked column sweep: `out[j] = Σ_i col_j[rows[i]] · r[i]`,
    /// the cross-validation fold kernel. Each block of `PANEL_BLOCK`
    /// columns has its kept rows gathered into a compact per-worker
    /// panel (allocated once per worker, reused across that worker's
    /// column range) and reduced with `blas::dot_panel` — exactly the
    /// accumulation sequence a materialized row-subset design would
    /// see, so results are bitwise identical to the host-side
    /// `cv::FoldView` kernels at any thread count.
    fn par_masked_sweep(&self, data: &[f64], n: usize, rows: &[usize], r: &[f64], out: &mut [f64]) {
        let m = rows.len();
        let t = self.pool_size(out.len(), m);
        if t <= 1 {
            let mut panel = vec![0.0; blas::PANEL_BLOCK * m];
            masked_sweep_chunk(data, n, 0, rows, r, out, &mut panel);
            return;
        }
        let chunk = div_ceil(out.len(), t);
        // One gather panel per worker, allocated outside the spawn loop
        // (the no-hot-alloc policy) and outside the workers' own column
        // loops.
        let workers = div_ceil(out.len(), chunk);
        let mut panels: Vec<Vec<f64>> = (0..workers)
            .map(|_| vec![0.0; blas::PANEL_BLOCK * m])
            .collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for ((ci, co), panel) in out.chunks_mut(chunk).enumerate().zip(panels.iter_mut()) {
                let lo = ci * chunk;
                handles.push(s.spawn(move || masked_sweep_chunk(data, n, lo, rows, r, co, panel)));
            }
            for h in handles {
                h.join().expect("masked sweep worker panicked");
            }
        });
    }

    fn check_vectors(design: &RegisteredDesign, y: &[f64], eta: &[f64]) -> Result<()> {
        if y.len() != design.n || eta.len() != design.n {
            return Err(crate::err!(
                "y/eta have lengths {}/{}, expected {}",
                y.len(),
                eta.len(),
                design.n
            ));
        }
        Ok(())
    }
}

/// Serial masked sweep over columns `lo..lo + out.len()` of the
/// col-major `data`: gather each `PANEL_BLOCK`-wide block of columns'
/// kept rows into `panel` (caller-allocated, reused across blocks),
/// then reduce against `r` with `blas::dot_panel`. The gather copies
/// stored entries verbatim, so each output equals the scalar
/// `blas::dot` of the compacted column bitwise.
fn masked_sweep_chunk(
    data: &[f64],
    n: usize,
    lo: usize,
    rows: &[usize],
    r: &[f64],
    out: &mut [f64],
    panel: &mut [f64],
) {
    let m = rows.len();
    let mut j = 0;
    while j < out.len() {
        let b = blas::PANEL_BLOCK.min(out.len() - j);
        for k in 0..b {
            let col = &data[(lo + j + k) * n..(lo + j + k + 1) * n];
            let dst = &mut panel[k * m..(k + 1) * m];
            for (d, &i) in dst.iter_mut().zip(rows) {
                *d = col[i];
            }
        }
        blas::dot_panel(&panel[..b * m], m, r, &mut out[j..j + b]);
        j += b;
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn num_ops(&self) -> usize {
        NATIVE_OPS
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn supports_sweep(&self, loss: Loss, _n: usize, _p: usize) -> bool {
        // Shape-agnostic: the native kernels are not compiled per shape.
        // Poisson is excluded to mirror the artifact surface (no
        // Lipschitz gradient, no fused sweep — paper App. F.9).
        !matches!(loss, Loss::Poisson)
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        if col_major.len() != n * p {
            return Err(crate::err!(
                "design buffer has {} entries, expected {}x{}",
                col_major.len(),
                n,
                p
            ));
        }
        let col_norms = (0..p)
            .map(|j| blas::nrm2(Self::column(col_major, n, j)))
            .collect();
        Ok(RegisteredDesign {
            n,
            p,
            col_norms,
            repr: DesignRepr::Native(col_major.to_vec()),
        })
    }

    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let mut c = Vec::new();
        Ok(self.correlation_into(design, r, &mut c)?.then_some(c))
    }

    fn correlation_into(
        &self,
        design: &RegisteredDesign,
        r: &[f64],
        c: &mut Vec<f64>,
    ) -> Result<bool> {
        let data = Self::design_data(design)?;
        if r.len() != design.n {
            return Err(crate::err!(
                "residual has length {}, expected {}",
                r.len(),
                design.n
            ));
        }
        c.resize(design.p, 0.0);
        self.par_sweep(data, design.n, r, c);
        Ok(true)
    }

    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let (mut c, mut resid) = (Vec::new(), Vec::new());
        Ok(self
            .kkt_sweep_into(loss, design, y, eta, lambda, &mut c, &mut resid)?
            .then_some((c, resid)))
    }

    fn kkt_sweep_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        _lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        if matches!(loss, Loss::Poisson) {
            return Ok(false);
        }
        let data = Self::design_data(design)?;
        Self::check_vectors(design, y, eta)?;
        resid.resize(design.n, 0.0);
        loss.pseudo_residual_into(y, eta, resid);
        c.resize(design.p, 0.0);
        self.par_sweep(data, design.n, resid, c);
        Ok(true)
    }

    fn kkt_sweep_masked(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let (mut c, mut resid) = (Vec::new(), Vec::new());
        Ok(self
            .kkt_sweep_masked_into(loss, design, rows, y, eta, lambda, &mut c, &mut resid)?
            .then_some((c, resid)))
    }

    fn kkt_sweep_masked_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        rows: &[usize],
        y: &[f64],
        eta: &[f64],
        _lambda: f64,
        c: &mut Vec<f64>,
        resid: &mut Vec<f64>,
    ) -> Result<bool> {
        if matches!(loss, Loss::Poisson) {
            return Ok(false);
        }
        let data = Self::design_data(design)?;
        let m = rows.len();
        if y.len() != m || eta.len() != m {
            return Err(crate::err!(
                "masked sweep: y/eta have lengths {}/{}, expected the fold size {}",
                y.len(),
                eta.len(),
                m
            ));
        }
        if let Some(&bad) = rows.iter().find(|&&i| i >= design.n) {
            return Err(crate::err!(
                "masked sweep: row index {bad} out of bounds for n = {}",
                design.n
            ));
        }
        resid.resize(m, 0.0);
        loss.pseudo_residual_into(y, eta, resid);
        c.resize(design.p, 0.0);
        self.par_masked_sweep(data, design.n, rows, resid, c);
        Ok(true)
    }

    fn kkt_sweep_batch(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        let mut batch = KktBatch::default();
        Ok(self
            .kkt_sweep_batch_into(loss, design, y, eta, lambdas, l1_norm, &mut batch)?
            .then_some(batch))
    }

    fn kkt_sweep_batch_into(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
        batch: &mut KktBatch,
    ) -> Result<bool> {
        if matches!(loss, Loss::Poisson) || lambdas.is_empty() {
            return Ok(false);
        }
        let data = Self::design_data(design)?;
        Self::check_vectors(design, y, eta)?;
        batch.resid.resize(design.n, 0.0);
        loss.pseudo_residual_into(y, eta, &mut batch.resid);
        batch.c.resize(design.p, 0.0);
        self.par_sweep(data, design.n, &batch.resid, &mut batch.c);
        // One sweep, B masks: the per-λ sphere tests reuse c (Larsson
        // 2021 — the O(pB) mask pass is marginal next to the O(np)
        // sweep it amortizes). Mask buffers are reused across batches.
        let xt_inf = blas::amax(&batch.c);
        batch.keep.truncate(lambdas.len());
        batch.keep.resize_with(lambdas.len(), Vec::new);
        for (keep, &l) in batch.keep.iter_mut().zip(lambdas) {
            let gap = loss.duality_gap(y, eta, &batch.resid, xt_inf, l, l1_norm);
            crate::screening::lookahead_keep_into(
                &batch.c,
                &design.col_norms,
                xt_inf,
                gap,
                l,
                0.0,
                keep,
            );
        }
        Ok(true)
    }

    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        let mut out = Vec::new();
        Ok(self
            .gram_block_into(xe_t, w, xd_t, e, d, n, &mut out)?
            .then_some(out))
    }

    fn gram_block_into(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
        out: &mut Vec<f64>,
    ) -> Result<bool> {
        if xe_t.len() != e * n || xd_t.len() != d * n || w.is_some_and(|w| w.len() != n) {
            return Err(crate::err!(
                "gram_block shape mismatch: xe {}, xd {}, w {} for (e={e}, d={d}, n={n})",
                xe_t.len(),
                xd_t.len(),
                w.map_or(n, <[f64]>::len)
            ));
        }
        out.resize(e * d, 0.0);
        if e * d == 0 {
            return Ok(true);
        }
        // Row-major (e, d) panel: out[a*d + b] = Σ_i xe[a,i] w[i] xd[b,i].
        // Each row streams xa once against PANEL_BLOCK xd columns; the
        // per-entry accumulation is exactly the scalar dot / dot_w
        // (products commute bitwise, and dot_w rounds w·xa once before
        // meeting the column — see blas::dot_w_block).
        self.par_map_rows(e, d, out, d * n, |a, row| {
            let xa = &xe_t[a * n..(a + 1) * n];
            match w {
                None => blas::dot_panel(xd_t, n, xa, row),
                Some(w) => blas::dot_w_panel(xd_t, n, xa, w, row),
            }
        });
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DenseMatrix, Design};
    use crate::testkit::Gen;

    #[test]
    fn register_rejects_bad_shape() {
        let b = NativeBackend::default();
        assert!(b.register_design(&[1.0, 2.0, 3.0], 2, 2).is_err());
    }

    #[test]
    fn register_caches_column_norms() {
        let mut g = Gen::new(4);
        let m = g.gaussian_matrix(17, 6);
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), 17, 6).unwrap();
        for j in 0..6 {
            assert_eq!(reg.col_norms[j], m.col_sq_norm(j).sqrt(), "col {j}");
        }
    }

    #[test]
    fn kkt_sweep_matches_pseudo_residual_path() {
        let mut g = Gen::new(5);
        let m = g.gaussian_matrix(25, 10);
        let y = g.gaussian_vec(25);
        let eta = g.gaussian_vec(25);
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), 25, 10).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let (c, resid) = b.kkt_sweep(loss, &reg, &y, &eta, 0.7).unwrap().unwrap();
            let mut resid_ref = vec![0.0; 25];
            loss.pseudo_residual_into(&y, &eta, &mut resid_ref);
            for i in 0..25 {
                assert!((resid[i] - resid_ref[i]).abs() < 1e-14);
            }
            for j in 0..10 {
                assert!((c[j] - m.col_dot(j, &resid_ref)).abs() < 1e-12);
            }
        }
        assert!(b.kkt_sweep(Loss::Poisson, &reg, &y, &eta, 0.7).unwrap().is_none());
    }

    #[test]
    fn threaded_kernels_are_bit_identical() {
        // Shape large enough to clear the flop cutoff so threads
        // actually spawn.
        let (n, p) = (64, 8_192);
        let mut g = Gen::new(21);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let eta = g.gaussian_vec(n);
        let serial = NativeBackend::default();
        let par = NativeBackend::new(4);
        assert_eq!(par.threads(), 4);
        let rs = serial.register_design(m.data(), n, p).unwrap();
        let rp = par.register_design(m.data(), n, p).unwrap();
        let cs = serial.correlation(&rs, &y).unwrap().unwrap();
        let cp = par.correlation(&rp, &y).unwrap().unwrap();
        assert_eq!(cs, cp, "threaded correlation must be bit-identical");
        let (ks, _) = serial.kkt_sweep(Loss::Logistic, &rs, &y, &eta, 0.5).unwrap().unwrap();
        let (kp, _) = par.kkt_sweep(Loss::Logistic, &rp, &y, &eta, 0.5).unwrap().unwrap();
        assert_eq!(ks, kp, "threaded kkt_sweep must be bit-identical");
    }

    #[test]
    fn batch_matches_per_lambda_sweeps() {
        let (n, p) = (40, 120);
        let mut g = Gen::new(9);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let eta = vec![0.0; n];
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), n, p).unwrap();
        let lambdas = [0.9, 0.7, 0.5];
        let batch = b
            .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &lambdas, 0.0)
            .unwrap()
            .unwrap();
        assert_eq!(batch.keep.len(), 3);
        let (c_seq, resid_seq) = b
            .kkt_sweep(Loss::Gaussian, &reg, &y, &eta, 0.9)
            .unwrap()
            .unwrap();
        assert_eq!(batch.c, c_seq, "batched c must equal the per-λ sweep");
        assert_eq!(batch.resid, resid_seq);
        // Masks match a direct evaluation of the sphere test.
        let xt_inf = blas::amax(&batch.c);
        for (l, &lam) in lambdas.iter().enumerate() {
            let gap = Loss::Gaussian.duality_gap(&y, &eta, &batch.resid, xt_inf, lam, 0.0);
            let want =
                crate::screening::lookahead_keep(&batch.c, &reg.col_norms, xt_inf, gap, lam, 0.0);
            assert_eq!(batch.keep[l], want, "mask {l}");
        }
        // Poisson and empty batches are unavailable, not errors.
        assert!(b
            .kkt_sweep_batch(Loss::Poisson, &reg, &y, &eta, &lambdas, 0.0)
            .unwrap()
            .is_none());
        assert!(b
            .kkt_sweep_batch(Loss::Gaussian, &reg, &y, &eta, &[], 0.0)
            .unwrap()
            .is_none());
    }

    #[test]
    fn masked_sweep_matches_materialized_subset_bitwise() {
        let (n, p) = (37, 23);
        let mut g = Gen::new(17);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let eta_full = g.gaussian_vec(n);
        let rows: Vec<usize> = (0..n).filter(|i| i % 4 != 2).collect();
        let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
        let ef: Vec<f64> = rows.iter().map(|&i| eta_full[i]).collect();
        // Materialized oracle: copy the kept rows out and run the
        // ordinary (unmasked) sweep on the subset design.
        let mut sub = vec![0.0; rows.len() * p];
        for j in 0..p {
            let col = m.col(j);
            for (r, &i) in rows.iter().enumerate() {
                sub[j * rows.len() + r] = col[i];
            }
        }
        let b = NativeBackend::default();
        let reg = b.register_design(m.data(), n, p).unwrap();
        let reg_sub = b.register_design(&sub, rows.len(), p).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let (cm, rm) = b
                .kkt_sweep_masked(loss, &reg, &rows, &yf, &ef, 0.5)
                .unwrap()
                .expect("native masked kernel");
            let (cs, rs) = b.kkt_sweep(loss, &reg_sub, &yf, &ef, 0.5).unwrap().unwrap();
            assert_eq!(rm, rs, "masked residual must equal the subset residual");
            for j in 0..p {
                assert_eq!(
                    cm[j].to_bits(),
                    cs[j].to_bits(),
                    "masked sweep differs from materialized subset at col {j} ({loss:?})"
                );
            }
        }
        // Poisson: unavailable, not an error.
        assert!(b
            .kkt_sweep_masked(Loss::Poisson, &reg, &rows, &yf, &ef, 0.5)
            .unwrap()
            .is_none());
        // Shape and bounds violations are errors.
        assert!(b
            .kkt_sweep_masked(Loss::Gaussian, &reg, &rows, &y, &ef, 0.5)
            .is_err());
        assert!(b
            .kkt_sweep_masked(Loss::Gaussian, &reg, &[n], &yf[..1], &ef[..1], 0.5)
            .is_err());
    }

    #[test]
    fn threaded_masked_sweep_is_bit_identical() {
        // Shape large enough to clear the flop cutoff so threads
        // actually spawn, with a ragged tail (p % PANEL_BLOCK != 0).
        let (n, p) = (96, 8_191);
        let mut g = Gen::new(23);
        let m = g.gaussian_matrix(n, p);
        let y = g.gaussian_vec(n);
        let rows: Vec<usize> = (0..n).filter(|i| i % 3 != 1).collect();
        let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
        let ef = vec![0.0; rows.len()];
        let serial = NativeBackend::default();
        let par = NativeBackend::new(4);
        let rs = serial.register_design(m.data(), n, p).unwrap();
        let rp = par.register_design(m.data(), n, p).unwrap();
        let (cs, _) = serial
            .kkt_sweep_masked(Loss::Gaussian, &rs, &rows, &yf, &ef, 0.5)
            .unwrap()
            .unwrap();
        let (cp, _) = par
            .kkt_sweep_masked(Loss::Gaussian, &rp, &rows, &yf, &ef, 0.5)
            .unwrap()
            .unwrap();
        assert_eq!(cs, cp, "threaded masked sweep must be bit-identical");
    }

    #[test]
    fn gram_block_matches_weighted_gram() {
        let (e, d, n) = (4, 3, 20);
        let mut g = Gen::new(6);
        let m: DenseMatrix = g.gaussian_matrix(n, e + d);
        let w: Vec<f64> = (0..n).map(|i| 0.1 + (i % 3) as f64 * 0.4).collect();
        let mut xe_t = Vec::with_capacity(e * n);
        for j in 0..e {
            xe_t.extend_from_slice(m.col(j));
        }
        let mut xd_t = Vec::with_capacity(d * n);
        for j in e..e + d {
            xd_t.extend_from_slice(m.col(j));
        }
        let b = NativeBackend::default();
        let panel = b.gram_block(&xe_t, Some(&w), &xd_t, e, d, n).unwrap().unwrap();
        for a in 0..e {
            for bb in 0..d {
                let want = m.gram_weighted(a, e + bb, Some(&w));
                assert!(
                    (panel[a * d + bb] - want).abs() < 1e-12,
                    "panel ({a},{bb})"
                );
            }
        }
        // Unweighted panels use the plain dot kernel — bit-identical
        // to Design::gram.
        let unw = b.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        for a in 0..e {
            for bb in 0..d {
                assert_eq!(unw[a * d + bb], m.gram(a, e + bb), "unweighted ({a},{bb})");
            }
        }
        assert!(b.gram_block(&xe_t, Some(&w), &xd_t, e, d, n + 1).is_err());
        assert_eq!(
            b.gram_block(&[], None, &xd_t, 0, d, n).unwrap().unwrap(),
            Vec::<f64>::new()
        );
    }

    #[test]
    fn threaded_gram_block_is_bit_identical() {
        let (e, d, n) = (96, 64, 50);
        let mut g = Gen::new(13);
        let m: DenseMatrix = g.gaussian_matrix(n, e + d);
        let mut xe_t = Vec::with_capacity(e * n);
        for j in 0..e {
            xe_t.extend_from_slice(m.col(j));
        }
        let mut xd_t = Vec::with_capacity(d * n);
        for j in e..e + d {
            xd_t.extend_from_slice(m.col(j));
        }
        let serial = NativeBackend::default();
        let par = NativeBackend::new(3);
        let a = serial.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        let b = par.gram_block(&xe_t, None, &xd_t, e, d, n).unwrap().unwrap();
        assert_eq!(a, b);
    }
}
