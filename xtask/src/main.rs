//! `cargo xtask` — repo automation, following the zero-dependency
//! "cargo xtask" pattern: build tooling lives in a workspace member so
//! `cargo run -p xtask -- <task>` works wherever cargo does, with no
//! external scripts or toolchain beyond the one that builds the crate.
//!
//! Tasks:
//!   lint    the project-invariant linter (see `lint.rs` and the
//!           README "Correctness tooling" section); wired to
//!           `make lint` and the blocking CI tier.

mod lint;

use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint::run(&args[1..]),
        Some(other) => {
            eprintln!("xtask: unknown task `{other}`");
            usage();
            ExitCode::from(2)
        }
        None => {
            usage();
            ExitCode::from(2)
        }
    }
}

fn usage() {
    eprintln!("usage: cargo run -p xtask -- lint [--root <dir>] [--allow-dir <dir>]");
    eprintln!();
    eprintln!("  lint   enforce project invariants over the crate sources");
    eprintln!("         --root       source tree to scan (default rust/src)");
    eprintln!("         --allow-dir  allowlist directory (default xtask/lint/allow)");
}
