//! Bench: Table 1 / Table 4 — full-path time on the twelve real-data
//! analogues (quick preset shrinks the giant text corpora; see
//! DESIGN.md §3 for the substitution policy).

use hessian_screening::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        reps: 2,
        ..Default::default()
    };
    experiments::run_experiment("tab1", &cfg).expect("tab1");
}
