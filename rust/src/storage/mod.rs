//! Out-of-core design storage.
//!
//! The screening regimes this crate targets are exactly the ones where
//! the n×p design stops fitting in RAM, so registration must be able
//! to stream column panels from disk instead of slicing a resident
//! copy. This module provides that seam:
//!
//! * [`ColumnSource`] — the staging contract: contiguous column-range
//!   reads producing column-major `Vec<f64>` panels, plus precomputed
//!   per-column norms so [`crate::runtime::RegisteredDesign`] never
//!   needs a resident pass over the data.
//! * [`ResidentSource`] — wraps an in-memory column-major buffer; the
//!   classic `register_design(&[f64])` path routes through it.
//! * [`HxdSource`] / [`HxdWriter`] / [`pack_dense`] — the on-disk
//!   `.hxd` columnar format (see [`hxd`] for the byte layout): packed
//!   little-endian f64 column blocks, per-block FNV-1a checksums
//!   verified on every read, and a trailing manifest carrying the
//!   column norms.
//! * [`read_csv`] — CSV ingestion for `hx pack`.
//!
//! The sharded upload pipeline (`runtime/shard.rs`) pulls its panels
//! through this trait, so shard k+1 is staged from the source while
//! shard k uploads — with an on-disk source the peak transient
//! footprint drops from ~2× the design to the engines' own shards
//! plus two in-flight panels. The same seam is where a future PJRT
//! multi-device fan-out will load from.
//!
//! Cross-validation composes with this layer for free: a
//! [`crate::cv::FoldView`] (and the engine's row-masked fold sweeps)
//! restricts *rows* of an already-registered design, so a k-fold CV
//! over a `ColumnSource`-backed design streams the file exactly once —
//! no per-fold re-registration, no per-fold design copies.
//!
//! Everything here is f64-exact (enforced by the xtask linter's no-f32
//! rule) and clock-free (the kernel clock ban covers `storage/`):
//! timing of reads belongs to the pipeline that calls us.

#![forbid(unsafe_code)]

mod csv;
mod hxd;

pub use csv::read_csv;
pub use hxd::{pack_dense, HxdSource, HxdWriter, PackSummary, DEFAULT_BLOCK_COLS, HXD_VERSION};

use crate::error::Result;
use crate::linalg::blas;

/// A provider of contiguous column panels for design registration.
///
/// Implementations promise that `read_cols(c0, c1)` returns the exact
/// bits of columns `c0..c1` in column-major order (`(c1-c0)·n` values)
/// and that [`ColumnSource::col_norms`] equals `blas::nrm2` of each
/// column bitwise — the sharded reduction layer rebuilds keep-masks
/// from these norms, so an approximate norm would silently unsound the
/// screen.
pub trait ColumnSource: Send {
    /// Number of rows (observations).
    fn n(&self) -> usize;

    /// Number of columns (features).
    fn p(&self) -> usize;

    /// Per-column ℓ2 norms, bitwise equal to `blas::nrm2` on the
    /// column data this source serves.
    fn col_norms(&self) -> &[f64];

    /// Read columns `c0..c1` as one contiguous column-major panel.
    /// `c0 == c1` yields an empty panel (degenerate shards are legal).
    fn read_cols(&mut self, c0: usize, c1: usize) -> Result<Vec<f64>>;

    /// Cumulative bytes pulled from the underlying storage so far
    /// (file reads or resident copies). The upload pipeline reports
    /// deltas of this through `UploadStats::bytes_read`.
    fn bytes_read(&self) -> u64;

    /// Short identifier for diagnostics: `"resident"`, `"hxd"`.
    fn source_name(&self) -> &'static str;
}

/// Column range sanity shared by every source.
fn check_range(c0: usize, c1: usize, p: usize) -> Result<()> {
    if c0 > c1 || c1 > p {
        return Err(crate::err!("column range {c0}..{c1} out of bounds for p = {p}"));
    }
    Ok(())
}

/// 64-bit FNV-1a over a byte slice (the `.hxd` checksum; zero-dep).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(0xcbf2_9ce4_8422_2325, bytes)
}

/// Streaming FNV-1a step: fold `bytes` into running hash `h`.
pub(crate) fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A [`ColumnSource`] over an in-memory column-major buffer.
///
/// This is the resident end of the seam: `register_design(&[f64])`
/// wraps its input in one of these, so the pipeline has a single
/// staging code path whether the design lives in RAM or on disk.
pub struct ResidentSource {
    n: usize,
    p: usize,
    data: Vec<f64>,
    col_norms: Vec<f64>,
    bytes_read: u64,
}

impl ResidentSource {
    /// Take ownership of a column-major buffer of `n`×`p` values.
    pub fn new(data: Vec<f64>, n: usize, p: usize) -> Result<Self> {
        let expect = n
            .checked_mul(p)
            .ok_or_else(|| crate::err!("design shape {n}x{p} overflows usize"))?;
        if data.len() != expect {
            return Err(crate::err!(
                "design buffer has {} entries, expected {n}x{p} = {expect}",
                data.len()
            ));
        }
        let col_norms = (0..p).map(|j| blas::nrm2(&data[j * n..(j + 1) * n])).collect();
        Ok(Self { n, p, data, col_norms, bytes_read: 0 })
    }

    /// Copy a borrowed column-major slice (the `register_design` path).
    pub fn copy_of(col_major: &[f64], n: usize, p: usize) -> Result<Self> {
        Self::new(col_major.to_vec(), n, p)
    }
}

impl ColumnSource for ResidentSource {
    fn n(&self) -> usize {
        self.n
    }

    fn p(&self) -> usize {
        self.p
    }

    fn col_norms(&self) -> &[f64] {
        &self.col_norms
    }

    fn read_cols(&mut self, c0: usize, c1: usize) -> Result<Vec<f64>> {
        check_range(c0, c1, self.p)?;
        let panel = self.data[c0 * self.n..c1 * self.n].to_vec();
        self.bytes_read += 8 * panel.len() as u64;
        Ok(panel)
    }

    fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    fn source_name(&self) -> &'static str {
        "resident"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // Streaming in two chunks equals one pass.
        let whole = fnv1a64(b"hessian");
        let split = fnv1a64_update(fnv1a64(b"hess"), b"ian");
        assert_eq!(whole, split);
    }

    #[test]
    fn resident_source_reads_exact_bits_and_counts_bytes() {
        let (n, p) = (3, 4);
        let data: Vec<f64> = (0..n * p).map(|i| i as f64 * 0.5 - 1.0).collect();
        let mut src = ResidentSource::copy_of(&data, n, p).expect("valid shape");
        assert_eq!(src.n(), n);
        assert_eq!(src.p(), p);
        assert_eq!(src.source_name(), "resident");
        let panel = src.read_cols(1, 3).expect("in range");
        assert_eq!(panel, &data[n..3 * n]);
        assert_eq!(src.bytes_read(), (2 * n * 8) as u64);
        // Empty range is legal (degenerate shards).
        assert!(src.read_cols(2, 2).expect("empty ok").is_empty());
        // Norms match a direct nrm2 bitwise.
        for j in 0..p {
            let direct = blas::nrm2(&data[j * n..(j + 1) * n]);
            assert_eq!(src.col_norms()[j].to_bits(), direct.to_bits());
        }
    }

    #[test]
    fn resident_source_rejects_bad_shapes_and_ranges() {
        let err = ResidentSource::new(vec![0.0; 5], 2, 3).expect_err("5 != 6");
        assert!(err.to_string().contains("expected 2x3"), "got: {err}");
        let mut src = ResidentSource::new(vec![0.0; 6], 2, 3).expect("valid");
        let err = src.read_cols(2, 4).expect_err("past p");
        assert!(err.to_string().contains("out of bounds"), "got: {err}");
        let err = src.read_cols(2, 1).expect_err("inverted");
        assert!(err.to_string().contains("out of bounds"), "got: {err}");
    }
}
