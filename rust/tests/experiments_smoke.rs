//! Integration: every registered experiment runs end-to-end on a tiny
//! budget and writes its CSV outputs. This is the "does `hx exp all`
//! work" guarantee, at 1 rep and miniature sizes.

use hessian_screening::experiments::{self, ExpConfig};

fn tiny_cfg(dir: &std::path::Path) -> ExpConfig {
    ExpConfig {
        reps: 1,
        full: false,
        out_dir: Some(dir.to_path_buf()),
        threads: 2,
        seed: 123,
    }
}

struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("hx-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn fig2_and_fig9_and_fig12_run_and_write_csv() {
    // A representative subset covering all three output styles (summary
    // table, per-step series, breakdown). The rest are size-hungry and
    // covered by their module unit tests + the bench binaries.
    let tmp = TempDir::new("exps");
    let mut cfg = tiny_cfg(&tmp.0);
    cfg.reps = 1;

    experiments::run_experiment("fig9", &cfg).expect("fig9");
    assert!(tmp.0.join("fig9_gamma.csv").exists());

    experiments::run_experiment("fig12", &cfg).expect("fig12");
    assert!(tmp.0.join("fig12_breakdown.csv").exists());
    assert!(tmp.0.join("fig12_series.csv").exists());
    let series = std::fs::read_to_string(tmp.0.join("fig12_series.csv")).unwrap();
    assert!(series.lines().count() > 10, "per-step series too short");
    assert!(series.starts_with("dataset,method,step,lambda"));
}

#[test]
fn fig10_ablation_runs() {
    let tmp = TempDir::new("abl");
    let cfg = tiny_cfg(&tmp.0);
    experiments::run_experiment("fig10", &cfg).expect("fig10");
    let csv = std::fs::read_to_string(tmp.0.join("fig10_ablation.csv")).unwrap();
    // all five variants present
    for v in ["vanilla", "+ screening", "+ warm starts", "+ sweep updates", "+ gap safe"] {
        assert!(csv.contains(v), "missing variant {v}");
    }
}

#[test]
fn tab1_subset_runs_on_small_sets() {
    let tmp = TempDir::new("tab1");
    let cfg = tiny_cfg(&tmp.0);
    experiments::real_data::run_subset(
        &cfg,
        Some(&["colon-cancer".to_string(), "duke-breast-cancer".to_string()]),
    )
    .expect("tab1 subset");
    let csv = std::fs::read_to_string(tmp.0.join("tab1_real_data.csv")).unwrap();
    assert!(csv.contains("colon-cancer"));
    assert!(csv.contains("hessian"));
    // 2 datasets x 4 methods + header
    assert_eq!(csv.lines().count(), 9);
}
