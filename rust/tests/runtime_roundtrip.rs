//! Integration: the compute-backend bridge, end to end.
//!
//! The native-backend roundtrips run unconditionally — they need no
//! artifacts and no feature flags, so `cargo test` exercises the whole
//! Backend → EngineSweep → path-driver chain on a fresh checkout.
//!
//! The PJRT artifact tests are compiled only with `--features pjrt`
//! and still skip politely when `make artifacts` has not been run, so
//! `cargo test --features pjrt` stays green without a Python toolchain
//! (`make test` always builds artifacts first).

use hessian_screening::data::{DesignMatrix, SyntheticSpec};
use hessian_screening::error::Result;
use hessian_screening::linalg::Design;
use hessian_screening::loss::Loss;
use hessian_screening::path::{PathFitter, PathSettings};
use hessian_screening::runtime::{
    Backend, EngineSweep, KktBatch, NativeBackend, RegisteredDesign, RuntimeEngine,
};
use hessian_screening::screening::{lookahead_keep, ScreeningKind};

fn dense_of(data: &hessian_screening::data::Dataset) -> &hessian_screening::linalg::DenseMatrix {
    match &data.design {
        DesignMatrix::Dense(m) => m,
        _ => unreachable!("test data is dense"),
    }
}

// ---------------------------------------------------------------------
// Native backend: unconditional roundtrips.
// ---------------------------------------------------------------------

#[test]
fn native_xt_r_matches_direct_sweep() {
    let engine = RuntimeEngine::native();
    let (n, p) = (120, 800);
    let data = SyntheticSpec::new(n, p, 8).rho(0.3).seed(3).generate();
    let dense = dense_of(&data);
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    let r = &data.response;
    let c = engine.correlation(&reg, r).unwrap().expect("native kernel");
    assert_eq!(c.len(), p);
    for j in 0..p {
        let native = dense.col_dot(j, r);
        assert!(
            (c[j] - native).abs() < 1e-10 * (1.0 + native.abs()),
            "col {j}: {} vs {}",
            c[j],
            native
        );
    }
}

#[test]
fn native_kkt_sweep_gaussian_and_logistic() {
    let engine = RuntimeEngine::native();
    let (n, p) = (100, 400);
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 8)
            .rho(0.2)
            .loss(loss)
            .seed(4)
            .generate();
        let dense = dense_of(&data);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let eta = vec![0.1; n];
        let (c, resid) = engine
            .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
            .unwrap()
            .expect("native kernel");
        let mut resid_native = vec![0.0; n];
        loss.pseudo_residual_into(&data.response, &eta, &mut resid_native);
        for i in 0..n {
            assert!(
                (resid[i] - resid_native[i]).abs() < 1e-12,
                "{loss:?} resid {i}"
            );
        }
        for j in 0..p {
            let native = dense.col_dot(j, &resid_native);
            assert!(
                (c[j] - native).abs() < 1e-10 * (1.0 + native.abs()),
                "{loss:?} col {j}: {} vs {native}",
                c[j]
            );
        }
    }
}

#[test]
fn native_gram_block_matches_weighted_gram() {
    let engine = RuntimeEngine::native();
    let (e, d, n) = (32, 8, 100);
    let data = SyntheticSpec::new(n, e + d, 5).seed(5).generate();
    let dense = dense_of(&data);
    // Row-major (e, n) panels == concatenated column-major columns.
    let mut xe_t = Vec::with_capacity(e * n);
    for j in 0..e {
        xe_t.extend_from_slice(dense.col(j));
    }
    let mut xd_t = Vec::with_capacity(d * n);
    for j in e..e + d {
        xd_t.extend_from_slice(dense.col(j));
    }
    let w = vec![0.25; n];
    let g = engine
        .gram_block(&xe_t, Some(&w), &xd_t, e, d, n)
        .unwrap()
        .expect("native kernel");
    assert_eq!(g.len(), e * d);
    for a in 0..e {
        for b in 0..d {
            let native = 0.25 * dense.gram(a, e + b);
            let got = g[a * d + b]; // row-major (e, d)
            assert!(
                (got - native).abs() < 1e-10 * (1.0 + native.abs()),
                "panel ({a},{b}): {got} vs {native}"
            );
        }
    }
}

#[test]
fn native_engine_swept_path_equals_plain_path() {
    let engine = RuntimeEngine::native();
    let (n, p) = (150, 600);
    let data = SyntheticSpec::new(n, p, 10).rho(0.4).seed(6).generate();
    let dense = dense_of(&data);
    // Look-ahead off: this test isolates the per-λ full_sweep path
    // against the no-engine driver (the batched path has its own
    // equivalence tests below).
    let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
        .unwrap()
        .expect("native backend always binds")
        .with_lookahead(0);
    let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
    let native = fitter.fit(&data.design, &data.response);
    let swept = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
    assert_eq!(native.lambdas.len(), swept.lambdas.len());
    let m = native.lambdas.len();
    for k in 0..m {
        let a = native.beta_dense(k, p);
        let b = swept.beta_dense(k, p);
        for j in 0..p {
            assert!(
                (a[j] - b[j]).abs() < 1e-6,
                "step {k} coef {j}: {} vs {}",
                a[j],
                b[j]
            );
        }
    }
}

#[test]
fn native_poisson_has_no_fused_sweep() {
    // Poisson has no fused sweep by design (no Lipschitz gradient), so
    // EngineSweep::new must return None and the driver stays native.
    let engine = RuntimeEngine::native();
    assert!(!engine.supports_sweep(Loss::Poisson, 200, 2_000));
    let data = SyntheticSpec::new(40, 30, 3).seed(7).generate();
    let dense = dense_of(&data);
    assert!(EngineSweep::new(&engine, dense, Loss::Poisson)
        .unwrap()
        .is_none());
}

#[test]
fn load_dir_without_artifacts_errors_cleanly() {
    // Default builds: feature-gate error. `pjrt` builds: missing
    // manifest. Either way an Err the CLI can print — never a panic.
    let err = RuntimeEngine::load_dir(std::path::Path::new("/nonexistent-dir-xyz"));
    assert!(err.is_err());
}

// ---------------------------------------------------------------------
// Batched look-ahead sweeps + threaded kernels: equivalence tests.
// ---------------------------------------------------------------------

/// One batched sweep must return the *bit-identical* correlation
/// vector the per-λ sequential f64 path computes, for every loss with
/// a fused sweep — the batching only amortizes, never re-rounds.
#[test]
fn batched_sweep_bit_identical_to_sequential_gaussian_and_logistic() {
    for threads in [1usize, 4] {
        let engine = RuntimeEngine::native_threaded(threads);
        let (n, p) = (120, 900);
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let data = SyntheticSpec::new(n, p, 8)
                .rho(0.3)
                .loss(loss)
                .seed(11)
                .generate();
            let dense = dense_of(&data);
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            let eta = vec![0.05; n];
            let lambdas = [0.8, 0.6, 0.45, 0.3];
            let batch = engine
                .kkt_sweep_batch(loss, &reg, &data.response, &eta, &lambdas, 1.5)
                .unwrap()
                .expect("native batch kernel");
            assert_eq!(batch.keep.len(), lambdas.len());
            for &lam in &lambdas {
                let (c_seq, resid_seq) = engine
                    .kkt_sweep(loss, &reg, &data.response, &eta, lam)
                    .unwrap()
                    .expect("native kernel");
                assert_eq!(
                    batch.c, c_seq,
                    "{loss:?} t={threads}: batched c differs from per-λ sweep"
                );
                assert_eq!(batch.resid, resid_seq);
            }
            // Every mask equals the sphere test evaluated directly on
            // the exact correlation vector (same f64 formula, same
            // column norms — bit-identical decisions).
            let norms: Vec<f64> = (0..p).map(|j| dense.col_sq_norm(j).sqrt()).collect();
            let xt_inf = batch.c.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            for (l, &lam) in lambdas.iter().enumerate() {
                let gap =
                    loss.duality_gap(&data.response, &eta, &batch.resid, xt_inf, lam, 1.5);
                let want = lookahead_keep(&batch.c, &norms, xt_inf, gap, lam, 0.0);
                assert_eq!(batch.keep[l], want, "{loss:?} t={threads}: mask {l}");
            }
        }
    }
}

/// Threads are a wall-clock knob, not a numerics knob: the whole fitted
/// path must be bit-identical at any thread count (same look-ahead
/// batching, same backend kernels per column).
#[test]
fn threaded_engine_path_bit_identical_to_serial_engine_path() {
    // n·p clears the native backend's parallelism cutoff, so the
    // 4-thread engine really does spawn workers.
    let (n, p) = (150, 2_000);
    for loss in [Loss::Gaussian, Loss::Logistic] {
        let data = SyntheticSpec::new(n, p, 8)
            .rho(0.35)
            .loss(loss)
            .seed(17)
            .generate();
        let dense = dense_of(&data);
        let serial = RuntimeEngine::native_threaded(1);
        let par = RuntimeEngine::native_threaded(4);
        let sweep_s = EngineSweep::new(&serial, dense, loss).unwrap().unwrap();
        let sweep_p = EngineSweep::new(&par, dense, loss).unwrap().unwrap();
        let fitter = PathFitter::new(loss, ScreeningKind::Hessian);
        let a = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep_s));
        let b = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep_p));
        assert_eq!(a.lambdas.len(), b.lambdas.len(), "{loss:?}: path lengths");
        for k in 0..a.lambdas.len() {
            let ba = a.beta_dense(k, p);
            let bb = b.beta_dense(k, p);
            for j in 0..p {
                assert!(
                    ba[j] == bb[j],
                    "{loss:?} step {k} coef {j}: {} vs {} (threads must not change bits)",
                    ba[j],
                    bb[j]
                );
            }
        }
    }
}

/// The batched look-ahead path must (a) actually skip full sweeps and
/// (b) agree with the per-λ sequential engine path to solver slack.
#[test]
fn lookahead_path_skips_sweeps_and_matches_sequential() {
    let (n, p) = (110, 700);
    for (loss, kind) in [
        (Loss::Gaussian, ScreeningKind::Hessian),
        (Loss::Logistic, ScreeningKind::Working),
    ] {
        let data = SyntheticSpec::new(n, p, 9)
            .rho(0.3)
            .snr(2.0)
            .loss(loss)
            .seed(23)
            .generate();
        let dense = dense_of(&data);
        let engine = RuntimeEngine::native_threaded(2);
        let batched = EngineSweep::new(&engine, dense, loss).unwrap().unwrap();
        assert_eq!(batched.lookahead, 4, "default batch width");
        let sequential = EngineSweep::new(&engine, dense, loss)
            .unwrap()
            .unwrap()
            .with_lookahead(0);
        let mut settings = PathSettings::default();
        settings.path_length = 40;
        settings.cd.eps = 1e-8;
        let fitter = PathFitter::new(loss, kind).with_settings(settings);
        let a = fitter.fit_with_engine(&data.design, &data.response, Some(&batched));
        let b = fitter.fit_with_engine(&data.design, &data.response, Some(&sequential));

        let skips = a.steps.iter().filter(|s| s.lookahead_skip).count();
        assert!(skips > 0, "{loss:?}: look-ahead never skipped a sweep");
        assert_eq!(
            b.steps.iter().filter(|s| s.lookahead_skip).count(),
            0,
            "{loss:?}: sequential run must not use masks"
        );
        let sweeps_a: usize = a.steps.iter().map(|s| s.full_sweeps).sum();
        let sweeps_b: usize = b.steps.iter().map(|s| s.full_sweeps).sum();
        assert!(
            sweeps_a < sweeps_b,
            "{loss:?}: batching did not reduce sweeps ({sweeps_a} vs {sweeps_b})"
        );

        // Look-ahead only ever drops predictors that are provably zero
        // at the optimum, so both runs converge to the same solution;
        // transient working-set differences are bounded by the ε·ζ
        // duality-gap slack (same bound the cross-method tests use).
        let m = a.lambdas.len().min(b.lambdas.len());
        assert!(m > 5, "{loss:?}: paths too short ({m})");
        for k in 0..m {
            let ba = a.beta_dense(k, p);
            let bb = b.beta_dense(k, p);
            for j in 0..p {
                assert!(
                    (ba[j] - bb[j]).abs() < 1e-3,
                    "{loss:?} step {k} coef {j}: {} vs {}",
                    ba[j],
                    bb[j]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Reduced-precision backends: the f64 borderline re-verification.
// ---------------------------------------------------------------------

/// A deliberately-inexact mock backend: serves the native kernels but
/// perturbs every correlation lying inside the recheck band around λ
/// (flipping it across the threshold), the worst case for a reduced
/// precision (f32) backend. `is_exact()` stays false, so
/// `EngineSweep::full_sweep` must repair every decision in f64.
struct PerturbingBackend {
    inner: NativeBackend,
    band: f64,
}

impl PerturbingBackend {
    fn perturb(&self, c: &mut [f64], lambda: f64) {
        let (lo, hi) = (lambda * (1.0 - self.band), lambda * (1.0 + self.band));
        for cv in c.iter_mut() {
            let a = cv.abs();
            if a >= lo && a <= hi {
                // Flip across the threshold: violations become
                // passes and vice versa — maximally misleading.
                let flipped = if a > lambda {
                    lambda * (1.0 - 0.5 * self.band)
                } else {
                    lambda * (1.0 + 0.5 * self.band)
                };
                *cv = cv.signum() * flipped;
            }
        }
    }
}

impl Backend for PerturbingBackend {
    fn name(&self) -> &'static str {
        "perturbed"
    }

    fn num_ops(&self) -> usize {
        self.inner.num_ops()
    }

    fn supports_sweep(&self, loss: Loss, n: usize, p: usize) -> bool {
        self.inner.supports_sweep(loss, n, p)
    }

    // is_exact() deliberately left at the default `false`.

    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        self.inner.register_design(col_major, n, p)
    }

    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        self.inner.correlation(design, r)
    }

    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let Some((mut c, resid)) = self.inner.kkt_sweep(loss, design, y, eta, lambda)? else {
            return Ok(None);
        };
        self.perturb(&mut c, lambda);
        Ok(Some((c, resid)))
    }

    fn kkt_sweep_batch(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        lambdas: &[f64],
        l1_norm: f64,
    ) -> Result<Option<KktBatch>> {
        let Some(mut batch) =
            self.inner
                .kkt_sweep_batch(loss, design, y, eta, lambdas, l1_norm)?
        else {
            return Ok(None);
        };
        for &lam in lambdas {
            self.perturb(&mut batch.c, lam);
        }
        Ok(Some(batch))
    }

    fn gram_block(
        &self,
        xe_t: &[f64],
        w: Option<&[f64]>,
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        self.inner.gram_block(xe_t, w, xd_t, e, d, n)
    }
}

#[test]
fn f64_recheck_repairs_inexact_backend_decisions() {
    let (n, p) = (90, 400);
    let data = SyntheticSpec::new(n, p, 6).rho(0.4).seed(31).generate();
    let dense = dense_of(&data);
    let y = &data.response;
    let eta = vec![0.0; n];
    let resid = y.clone();
    // Pick λ so that several correlations sit inside the band.
    let mut mags: Vec<f64> = (0..p).map(|j| dense.col_dot(j, &resid).abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let lambda = mags[6];

    let band = 5e-4; // inside EngineSweep's default recheck_band = 1e-3
    let engine = RuntimeEngine::from_backend(Box::new(PerturbingBackend {
        inner: NativeBackend::default(),
        band,
    }));
    assert!(!engine.is_exact());
    let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
        .unwrap()
        .expect("mock binds");

    // The raw backend really is wrong: at least one KKT decision flips.
    let reg = engine.register_design(dense.data(), n, p).unwrap();
    let (c_raw, _) = engine
        .kkt_sweep(Loss::Gaussian, &reg, y, &eta, lambda)
        .unwrap()
        .unwrap();
    let mut raw_flips = 0;
    for j in 0..p {
        let exact = dense.col_dot(j, &resid);
        if (c_raw[j].abs() > lambda) != (exact.abs() > lambda) {
            raw_flips += 1;
        }
    }
    assert!(raw_flips > 0, "mock backend failed to flip any decision");

    // Through full_sweep, the f64 recheck restores every decision —
    // and every borderline value exactly.
    let mut c = vec![0.0; p];
    assert!(sweep.full_sweep(dense, y, &eta, &resid, lambda, &mut c));
    for j in 0..p {
        let exact = dense.col_dot(j, &resid);
        assert_eq!(
            c[j].abs() > lambda,
            exact.abs() > lambda,
            "col {j}: KKT decision depends on f32-style rounding"
        );
        let a = exact.abs();
        if a >= lambda * (1.0 - band) && a <= lambda * (1.0 + band) {
            assert_eq!(c[j], exact, "borderline col {j} not restored to f64");
        }
    }

    // Same policy on the batched path: the mock's perturbations all
    // lie inside the recheck band, so the corrected correlations are
    // exactly the f64 values and the rebuilt masks must equal the
    // sphere test on them.
    let lambdas = [lambda, 0.9 * lambda];
    let mut c2 = vec![0.0; p];
    let masks = sweep
        .look_ahead(dense, y, &eta, &resid, 0.0, &lambdas, &mut c2)
        .expect("mock batch");
    for j in 0..p {
        assert_eq!(c2[j], dense.col_dot(j, &resid), "col {j} not repaired");
    }
    let norms: Vec<f64> = (0..p).map(|j| dense.col_sq_norm(j).sqrt()).collect();
    let xt_inf = c2.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    for (l, &lam) in lambdas.iter().enumerate() {
        let gap = Loss::Gaussian.duality_gap(y, &eta, &resid, xt_inf, lam, 0.0);
        // Inexact backends rebuild masks with `recheck_band` of slack
        // on the sphere threshold (conservative keeps only).
        let want = lookahead_keep(&c2, &norms, xt_inf, gap, lam, sweep.recheck_band);
        assert_eq!(masks[l], want, "rebuilt mask {l} wrong");
        let exact_keep = lookahead_keep(&c2, &norms, xt_inf, gap, lam, 0.0);
        for j in 0..p {
            // Slack can only widen the mask, never drop a keeper.
            assert!(
                masks[l][j] || !exact_keep[j],
                "mask {l} col {j}: slack dropped an exact keeper"
            );
        }
    }
}

// ---------------------------------------------------------------------
// PJRT artifact tests: compiled only with `--features pjrt`, and they
// skip politely when `make artifacts` has not produced the artifacts.
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;

    fn engine() -> Option<RuntimeEngine> {
        // tests run from the package root
        match RuntimeEngine::load_default() {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("skipping PJRT integration test: {err}");
                None
            }
        }
    }

    #[test]
    fn xt_r_artifact_matches_native_within_f32() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        let data = SyntheticSpec::new(n, p, 10).rho(0.3).seed(3).generate();
        let dense = dense_of(&data);
        let reg = engine.register_design(dense.data(), n, p).unwrap();
        let r = &data.response;
        let c = engine.correlation(&reg, r).unwrap().expect("artifact");
        assert_eq!(c.len(), p);
        let scale: f64 = r.iter().map(|v| v * v).sum::<f64>().sqrt() * (n as f64).sqrt();
        for j in 0..p {
            let native = dense.col_dot(j, r);
            assert!(
                (c[j] - native).abs() < 1e-4 * scale.max(1.0),
                "col {j}: {} vs {}",
                c[j],
                native
            );
        }
    }

    #[test]
    fn kkt_sweep_artifact_gaussian_and_logistic() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let data = SyntheticSpec::new(n, p, 10)
                .rho(0.2)
                .loss(loss)
                .seed(4)
                .generate();
            let dense = dense_of(&data);
            let reg = engine.register_design(dense.data(), n, p).unwrap();
            let eta = vec![0.1; n];
            let (c, resid) = engine
                .kkt_sweep(loss, &reg, &data.response, &eta, 0.5)
                .unwrap()
                .expect("artifact");
            let mut resid_native = vec![0.0; n];
            loss.pseudo_residual_into(&data.response, &eta, &mut resid_native);
            for i in 0..n {
                assert!(
                    (resid[i] - resid_native[i]).abs() < 1e-5,
                    "{loss:?} resid {i}"
                );
            }
            for j in (0..p).step_by(97) {
                let native = dense.col_dot(j, &resid_native);
                assert!(
                    (c[j] - native).abs() < 1e-3 * (1.0 + native.abs()),
                    "{loss:?} col {j}: {} vs {native}",
                    c[j]
                );
            }
        }
    }

    #[test]
    fn engine_swept_path_equals_native_path() {
        let Some(engine) = engine() else { return };
        let (n, p) = (200, 2_000);
        let data = SyntheticSpec::new(n, p, 10).rho(0.4).seed(6).generate();
        let dense = dense_of(&data);
        let sweep = EngineSweep::new(&engine, dense, Loss::Gaussian)
            .unwrap()
            .expect("sweep artifact for 200x2000");
        let fitter = PathFitter::new(Loss::Gaussian, ScreeningKind::Hessian);
        let native = fitter.fit(&data.design, &data.response);
        let swept = fitter.fit_with_engine(&data.design, &data.response, Some(&sweep));
        assert_eq!(native.lambdas.len(), swept.lambdas.len());
        let m = native.lambdas.len();
        for k in 0..m {
            let a = native.beta_dense(k, p);
            let b = swept.beta_dense(k, p);
            for j in 0..p {
                assert!(
                    (a[j] - b[j]).abs() < 1e-3,
                    "step {k} coef {j}: {} vs {}",
                    a[j],
                    b[j]
                );
            }
        }
    }

    #[test]
    fn unsupported_shapes_fall_back_to_native() {
        let Some(engine) = engine() else { return };
        // 123 x 456 has no artifact: supports_sweep must say no, and
        // EngineSweep::new must return None so the driver stays native.
        assert!(!engine.supports_sweep(Loss::Gaussian, 123, 456));
        let data = SyntheticSpec::new(123, 456, 5).seed(7).generate();
        let dense = dense_of(&data);
        assert!(EngineSweep::new(&engine, dense, Loss::Gaussian)
            .unwrap()
            .is_none());
    }
}
