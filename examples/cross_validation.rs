//! Cross-validated λ selection — the workload the paper's introduction
//! uses to motivate fast path solvers ("the optimal λ is typically
//! unknown and must be estimated through model tuning, such as
//! cross-validation", §1). Runs 10-fold CV with the Hessian rule,
//! compares wall time against working+, and evaluates the selected
//! model on held-out test data.
//!
//!     cargo run --release --example cross_validation

use hessian_screening::cv::{cross_validate, CvSettings};
use hessian_screening::metrics::{fmt_secs, Table};
use hessian_screening::model::FittedModel;
use hessian_screening::prelude::*;

fn main() {
    // Train/test split of a correlated high-dimensional problem.
    let train = SyntheticSpec::new(300, 2_000, 12).rho(0.5).snr(3.0).seed(1).generate();
    let test = SyntheticSpec::new(500, 2_000, 12).rho(0.5).snr(3.0).seed(2).generate();

    let mut cv_settings = CvSettings::default();
    cv_settings.path.path_length = 60;

    // CV with both methods: same selection, different wall time.
    let mut table = Table::new(&["method", "cv time (s)", "lambda_min", "support"]);
    let mut chosen: Option<FittedModel> = None;
    for kind in [ScreeningKind::Hessian, ScreeningKind::Working] {
        let t = std::time::Instant::now();
        let cv = cross_validate(
            &train.design,
            &train.response,
            Loss::Gaussian,
            kind,
            &cv_settings,
        );
        let secs = t.elapsed().as_secs_f64();
        table.row(vec![
            kind.name().into(),
            fmt_secs(secs),
            format!("{:.4}", cv.lambda_min()),
            format!("{}", cv.selected_coefs(false).len()),
        ]);
        if kind == ScreeningKind::Hessian {
            chosen = Some(FittedModel::from_path(
                &cv.full_fit,
                cv.idx_min,
                train.p(),
                None,
            ));
        }
    }
    println!("{}", table.render());

    // Score the CV-selected model out of sample.
    let model = chosen.unwrap();
    let test_mse = model.score_mse(&test.design, &test.response);
    let null_mse = test.response.iter().map(|v| v * v).sum::<f64>() / test.response.len() as f64;
    println!(
        "held-out MSE {test_mse:.3} vs null {null_mse:.3} ({}% explained)",
        (100.0 * (1.0 - test_mse / null_mse)).round()
    );
    let truth = train.beta_true.as_ref().unwrap();
    let hits = model
        .support()
        .iter()
        .filter(|&&j| truth[j] != 0.0)
        .count();
    println!(
        "support: {} selected, {}/12 true signals recovered",
        model.support().len(),
        hits
    );
    assert!(test_mse < 0.6 * null_mse, "CV model must beat the null fit");
}
