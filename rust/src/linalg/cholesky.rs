//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Used by the Hessian machinery: the augmentation step of the sweep
//! update (Algorithm 1) needs S⁻¹ for the Schur complement
//! S = X_DᵀX_D − X_DᵀX_A Q X_AᵀX_D, and the initial H⁻¹ at the first
//! active set is formed by a Cholesky solve. LAPACK is unavailable, so
//! this is a straightforward right-looking factorization with
//! column-dot inner loops.

use super::blas;
use super::DenseMatrix;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: DenseMatrix,
}

#[derive(Debug, Clone, PartialEq)]
pub enum CholeskyError {
    /// Leading minor `k` is not positive definite.
    NotPositiveDefinite(usize),
    NotSquare,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(k) => {
                write!(f, "matrix not positive definite at pivot {k}")
            }
            CholeskyError::NotSquare => write!(f, "matrix not square"),
        }
    }
}

impl std::error::Error for CholeskyError {}

impl Cholesky {
    /// Factorize a symmetric positive-definite matrix (only the lower
    /// triangle of `a` is read).
    pub fn factor(a: &DenseMatrix) -> Result<Self, CholeskyError> {
        if a.nrows() != a.ncols() {
            return Err(CholeskyError::NotSquare);
        }
        let n = a.nrows();
        let mut l = DenseMatrix::zeros(n, n);
        for j in 0..n {
            // d = a_jj - sum_k l_jk^2
            let mut d = a.at(j, j);
            for k in 0..j {
                let ljk = l.at(j, k);
                d -= ljk * ljk;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(CholeskyError::NotPositiveDefinite(j));
            }
            let djj = d.sqrt();
            *l.at_mut(j, j) = djj;
            for i in j + 1..n {
                let mut s = a.at(i, j);
                for k in 0..j {
                    s -= l.at(i, k) * l.at(j, k);
                }
                *l.at_mut(i, j) = s / djj;
            }
        }
        Ok(Self { l })
    }

    pub fn dim(&self) -> usize {
        self.l.nrows()
    }

    pub fn l(&self) -> &DenseMatrix {
        &self.l
    }

    /// Solve A x = b in place.
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let n = self.dim();
        assert_eq!(b.len(), n);
        // Forward: L z = b.
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * b[k];
            }
            b[i] = s / self.l.at(i, i);
        }
        // Backward: Lᵀ x = z.
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in i + 1..n {
                s -= self.l.at(k, i) * b[k];
            }
            b[i] = s / self.l.at(i, i);
        }
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// A⁻¹ as a dense matrix (solves against the identity columns).
    pub fn inverse(&self) -> DenseMatrix {
        let n = self.dim();
        let mut inv = DenseMatrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e.iter_mut().for_each(|v| *v = 0.0);
            e[j] = 1.0;
            self.solve_in_place(&mut e);
            inv.col_mut(j).copy_from_slice(&e);
        }
        inv
    }

    /// log det A = 2 Σ log l_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Solve the SPD system A x = b directly (factor + solve), with a ridge
/// fallback: if factorization fails, retry with A + αI for increasing α.
/// This mirrors the paper's Appendix-C attitude: never let a borderline
/// Hessian kill the path.
pub fn solve_spd_ridge(a: &DenseMatrix, b: &[f64], alpha0: f64) -> Vec<f64> {
    if let Ok(ch) = Cholesky::factor(a) {
        return ch.solve(b);
    }
    let n = a.nrows();
    let mut alpha = alpha0.max(1e-12);
    loop {
        let mut aa = a.clone();
        for i in 0..n {
            *aa.at_mut(i, i) += alpha;
        }
        if let Ok(ch) = Cholesky::factor(&aa) {
            return ch.solve(b);
        }
        alpha *= 10.0;
        assert!(alpha < 1e12, "ridge fallback diverged");
    }
}

/// Relative residual ‖Ax − b‖/‖b‖, for tests.
pub fn rel_residual(a: &DenseMatrix, x: &[f64], b: &[f64]) -> f64 {
    let n = a.nrows();
    let mut r = vec![0.0; n];
    a.gemv(x, &mut r);
    for i in 0..n {
        r[i] -= b[i];
    }
    blas::nrm2(&r) / blas::nrm2(b).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn random_spd(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut b = DenseMatrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                *b.at_mut(i, j) = rng.next_gaussian();
            }
        }
        let mut a = b.t_gemm(&b);
        for i in 0..n {
            *a.at_mut(i, i) += n as f64; // well conditioned
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(8, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l().gemm(&ch.l().transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_accuracy() {
        let a = random_spd(12, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let b: Vec<f64> = (0..12).map(|_| rng.next_gaussian()).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let x = ch.solve(&b);
        assert!(rel_residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(6, 4);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let prod = a.gemm(&inv);
        assert!(prod.max_abs_diff(&DenseMatrix::identity(6)) < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = DenseMatrix::identity(3);
        *a.at_mut(1, 1) = -1.0;
        match Cholesky::factor(&a) {
            Err(CholeskyError::NotPositiveDefinite(1)) => {}
            other => panic!("expected NPD at pivot 1, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_square() {
        let a = DenseMatrix::zeros(2, 3);
        assert_eq!(Cholesky::factor(&a).unwrap_err(), CholeskyError::NotSquare);
    }

    #[test]
    fn ridge_fallback_on_singular() {
        // Rank-1 matrix: plain Cholesky fails, ridge version succeeds.
        let mut a = DenseMatrix::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                *a.at_mut(i, j) = 1.0;
            }
        }
        let x = solve_spd_ridge(&a, &[1.0, 1.0, 1.0], 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_det_matches_identity_scaling() {
        let mut a = DenseMatrix::identity(4);
        for i in 0..4 {
            *a.at_mut(i, i) = 2.0;
        }
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.log_det() - 4.0 * 2.0f64.ln()).abs() < 1e-12);
    }
}
