//! The pure-Rust compute backend: exact f64 kernels on top of
//! [`crate::linalg`]. This is the reference implementation of the
//! [`Backend`] surface — always available, no artifacts, no FFI — and
//! the baseline every accelerated backend is cross-checked against
//! (`rust/tests/runtime_roundtrip.rs`).

use super::{Backend, DesignRepr, RegisteredDesign};
use crate::error::Result;
use crate::linalg::blas;
use crate::loss::Loss;

/// Zero-state native backend.
pub struct NativeBackend;

/// The op kinds the native backend serves: xt_r, the fused KKT sweep
/// (Gaussian + logistic), and the weighted Gram panel.
const NATIVE_OPS: usize = 3;

impl NativeBackend {
    fn column(data: &[f64], n: usize, j: usize) -> &[f64] {
        &data[j * n..(j + 1) * n]
    }

    #[cfg(feature = "pjrt")]
    fn design_data(design: &RegisteredDesign) -> Result<&[f64]> {
        match &design.repr {
            DesignRepr::Native(data) => Ok(data),
            _ => Err(crate::err!(
                "design was registered with a different backend"
            )),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn design_data(design: &RegisteredDesign) -> Result<&[f64]> {
        let DesignRepr::Native(data) = &design.repr;
        Ok(data)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn num_ops(&self) -> usize {
        NATIVE_OPS
    }

    fn supports_sweep(&self, loss: Loss, _n: usize, _p: usize) -> bool {
        // Shape-agnostic: the native kernels are not compiled per shape.
        // Poisson is excluded to mirror the artifact surface (no
        // Lipschitz gradient, no fused sweep — paper App. F.9).
        !matches!(loss, Loss::Poisson)
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn register_design(&self, col_major: &[f64], n: usize, p: usize) -> Result<RegisteredDesign> {
        if col_major.len() != n * p {
            return Err(crate::err!(
                "design buffer has {} entries, expected {}x{}",
                col_major.len(),
                n,
                p
            ));
        }
        Ok(RegisteredDesign {
            n,
            p,
            repr: DesignRepr::Native(col_major.to_vec()),
        })
    }

    fn correlation(&self, design: &RegisteredDesign, r: &[f64]) -> Result<Option<Vec<f64>>> {
        let data = Self::design_data(design)?;
        if r.len() != design.n {
            return Err(crate::err!(
                "residual has length {}, expected {}",
                r.len(),
                design.n
            ));
        }
        let c = (0..design.p)
            .map(|j| blas::dot(Self::column(data, design.n, j), r))
            .collect();
        Ok(Some(c))
    }

    fn kkt_sweep(
        &self,
        loss: Loss,
        design: &RegisteredDesign,
        y: &[f64],
        eta: &[f64],
        _lambda: f64,
    ) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        if matches!(loss, Loss::Poisson) {
            return Ok(None);
        }
        let data = Self::design_data(design)?;
        if y.len() != design.n || eta.len() != design.n {
            return Err(crate::err!(
                "y/eta have lengths {}/{}, expected {}",
                y.len(),
                eta.len(),
                design.n
            ));
        }
        let mut resid = vec![0.0; design.n];
        loss.pseudo_residual_into(y, eta, &mut resid);
        let c: Vec<f64> = (0..design.p)
            .map(|j| blas::dot(Self::column(data, design.n, j), &resid))
            .collect();
        Ok(Some((c, resid)))
    }

    fn gram_block(
        &self,
        xe_t: &[f64],
        w: &[f64],
        xd_t: &[f64],
        e: usize,
        d: usize,
        n: usize,
    ) -> Result<Option<Vec<f64>>> {
        if xe_t.len() != e * n || xd_t.len() != d * n || w.len() != n {
            return Err(crate::err!(
                "gram_block shape mismatch: xe {}, xd {}, w {} for (e={e}, d={d}, n={n})",
                xe_t.len(),
                xd_t.len(),
                w.len()
            ));
        }
        // Row-major (e, d) panel: out[a*d + b] = Σ_i xe[a,i] w[i] xd[b,i].
        let mut out = vec![0.0; e * d];
        for a in 0..e {
            let xa = &xe_t[a * n..(a + 1) * n];
            for b in 0..d {
                let xb = &xd_t[b * n..(b + 1) * n];
                out[a * d + b] = blas::dot_w(xa, xb, w);
            }
        }
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DenseMatrix, Design};
    use crate::testkit::Gen;

    #[test]
    fn register_rejects_bad_shape() {
        let b = NativeBackend;
        assert!(b.register_design(&[1.0, 2.0, 3.0], 2, 2).is_err());
    }

    #[test]
    fn kkt_sweep_matches_pseudo_residual_path() {
        let mut g = Gen::new(5);
        let m = g.gaussian_matrix(25, 10);
        let y = g.gaussian_vec(25);
        let eta = g.gaussian_vec(25);
        let b = NativeBackend;
        let reg = b.register_design(m.data(), 25, 10).unwrap();
        for loss in [Loss::Gaussian, Loss::Logistic] {
            let (c, resid) = b.kkt_sweep(loss, &reg, &y, &eta, 0.7).unwrap().unwrap();
            let mut resid_ref = vec![0.0; 25];
            loss.pseudo_residual_into(&y, &eta, &mut resid_ref);
            for i in 0..25 {
                assert!((resid[i] - resid_ref[i]).abs() < 1e-14);
            }
            for j in 0..10 {
                assert!((c[j] - m.col_dot(j, &resid_ref)).abs() < 1e-12);
            }
        }
        assert!(b.kkt_sweep(Loss::Poisson, &reg, &y, &eta, 0.7).unwrap().is_none());
    }

    #[test]
    fn gram_block_matches_weighted_gram() {
        let (e, d, n) = (4, 3, 20);
        let mut g = Gen::new(6);
        let m: DenseMatrix = g.gaussian_matrix(n, e + d);
        let w: Vec<f64> = (0..n).map(|i| 0.1 + (i % 3) as f64 * 0.4).collect();
        let mut xe_t = Vec::with_capacity(e * n);
        for j in 0..e {
            xe_t.extend_from_slice(m.col(j));
        }
        let mut xd_t = Vec::with_capacity(d * n);
        for j in e..e + d {
            xd_t.extend_from_slice(m.col(j));
        }
        let b = NativeBackend;
        let panel = b.gram_block(&xe_t, &w, &xd_t, e, d, n).unwrap().unwrap();
        for a in 0..e {
            for bb in 0..d {
                let want = m.gram_weighted(a, e + bb, Some(&w));
                assert!(
                    (panel[a * d + bb] - want).abs() < 1e-12,
                    "panel ({a},{bb})"
                );
            }
        }
        assert!(b.gram_block(&xe_t, &w, &xd_t, e, d, n + 1).is_err());
    }
}
