//! Appendix F.8 (Figure 10): incremental feature ablation. Features are
//! added cumulatively in the paper's order:
//!
//!   vanilla → + Hessian screening → + Hessian warm starts
//!           → + sweep updates (Alg. 1) → + Gap-Safe augmentation
//!
//! "Vanilla" uses no screening (full working set) and standard warm
//! starts, exactly as the paper describes.

use super::*;
use crate::metrics::{sig_figs, Summary, Table};
use crate::path::PathSettings;

pub fn variants() -> Vec<(&'static str, ScreeningKind, PathSettings)> {
    let base = paper_settings;
    let mut v = Vec::new();
    {
        let mut s = base();
        s.use_gap_safe_aug = false;
        s.hessian_warm_starts = false;
        s.hessian_screening = false;
        s.hessian_sweep_updates = false;
        v.push(("vanilla", ScreeningKind::None, s));
    }
    {
        let mut s = base();
        s.use_gap_safe_aug = false;
        s.hessian_warm_starts = false;
        s.hessian_sweep_updates = false;
        v.push(("+ screening", ScreeningKind::Hessian, s));
    }
    {
        let mut s = base();
        s.use_gap_safe_aug = false;
        s.hessian_sweep_updates = false;
        v.push(("+ warm starts", ScreeningKind::Hessian, s));
    }
    {
        let mut s = base();
        s.use_gap_safe_aug = false;
        v.push(("+ sweep updates", ScreeningKind::Hessian, s));
    }
    v.push(("+ gap safe", ScreeningKind::Hessian, base()));
    v
}

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let (n, p, s) = cfg.appendix_dim();
    struct Cell {
        variant: usize,
        rho: f64,
        rep: u64,
    }
    let vs = variants();
    let mut cells = Vec::new();
    for variant in 0..vs.len() {
        for &rho in &[0.4, 0.8] {
            for rep in 0..cfg.reps as u64 {
                cells.push(Cell { variant, rho, rep });
            }
        }
    }
    let vs_ref = &vs;
    let results = cfg.coordinator().run_with_progress("fig10", cells, |_, c| {
        let data = simulate(n, p, s, c.rho, 2.0, Loss::Gaussian, cfg.cell_seed(6_000, c.rep));
        let (name, kind, settings) = &vs_ref[c.variant];
        let (_, secs) = fit_timed(&data, *kind, settings);
        (*name, c.rho, secs)
    });

    let mut table = Table::new(&["Variant", "rho", "Time (s)", "CI lo", "CI hi"]);
    for (name, _, _) in &vs {
        for &rho in &[0.4, 0.8] {
            let times: Vec<f64> = results
                .iter()
                .filter(|(v, r, _)| v == name && *r == rho)
                .map(|(_, _, t)| *t)
                .collect();
            let sm = Summary::of(&times);
            table.row(vec![
                name.to_string(),
                format!("{rho}"),
                format!("{}", sig_figs(sm.mean, 3)),
                format!("{}", sig_figs(sm.lo(), 3)),
                format!("{}", sig_figs(sm.hi(), 3)),
            ]);
        }
    }
    println!("\nFigure 10 — incremental feature ablation");
    println!("{}", table.render());
    write_csv(cfg, "fig10_ablation", &table);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_are_cumulative() {
        let vs = variants();
        assert_eq!(vs.len(), 5);
        assert_eq!(vs[0].1, ScreeningKind::None);
        assert!(!vs[0].2.hessian_warm_starts);
        assert!(vs[2].2.hessian_warm_starts);
        assert!(!vs[2].2.hessian_sweep_updates);
        assert!(vs[3].2.hessian_sweep_updates);
        assert!(vs[4].2.use_gap_safe_aug);
    }

    #[test]
    fn screening_beats_vanilla_on_wide_design() {
        let data = simulate(50, 1_500, 5, 0.4, 2.0, Loss::Gaussian, 11);
        let vs = variants();
        let (v_fit, _) = fit_timed(&data, vs[0].1, &vs[0].2);
        let (s_fit, _) = fit_timed(&data, vs[1].1, &vs[1].2);
        // screening shrinks the subproblem by orders of magnitude
        assert!(s_fit.mean_screened() * 5.0 < v_fit.mean_screened());
    }
}
