"""Layer 2: the JAX compute graphs AOT-compiled for the rust runtime.

The paper's contribution is a *pathwise coordination* algorithm; its
numeric hot spots (per §3.3.1/§3.3.4) are the correlation/KKT sweep and
the Hessian Gram panels. These are expressed here as jitted JAX
functions that call the Layer-1 Pallas kernels, so that a single
``jax.jit(...).lower()`` produces one fused HLO module per operation.
``aot.py`` lowers each at the fixed shapes the benchmark suite uses;
the rust runtime (rust/src/runtime/) loads the HLO text via PJRT and
calls it from the solve path. Python never runs at solve time.

Shape conventions (zero-copy with the rust side): the design matrix
appears as Xᵀ of shape (p, n) because rust stores X column-major
(n, p) and the raw buffer of a column-major (n, p) matrix *is* a
row-major (p, n) array. Vectors are (·, 1) columns.
"""

import jax.numpy as jnp

from .kernels import gram_block, xt_r


def correlation(xt: jnp.ndarray, r: jnp.ndarray, tp: int = 256, tn: int = 256) -> tuple:
    """c = Xᵀr — the screening/KKT sweep (Layer-1 kernel).

    ``tp``/``tn`` are the Pallas tile targets. Defaults are the TPU VMEM
    tiles documented in the kernel; the AOT path overrides them per
    backend (CPU interpret mode wants a collapsed grid — see
    EXPERIMENTS.md §Perf L1).
    """
    return (xt_r(xt, r, tp=tp, tn=tn),)


def lasso_kkt(
    xt: jnp.ndarray,
    y: jnp.ndarray,
    eta: jnp.ndarray,
    lam: jnp.ndarray,
    tp: int = 256,
    tn: int = 256,
) -> tuple:
    """Fused Gaussian-lasso KKT sweep: residual → correlation →
    violation mask in one module, so XLA fuses the elementwise work
    into the matvec stream (§3.3.4's "KKT checks" at marginal cost).

    ``y``/``eta``: (n, 1); ``lam``: scalar (0-d). Returns
    (c (p,1), resid (n,1), viol (p,1)).
    """
    resid = y - eta
    c = xt_r(xt, resid, tp=tp, tn=tn)
    viol = (jnp.abs(c) > lam).astype(xt.dtype)
    return c, resid, viol


def hessian_panel(xe_t: jnp.ndarray, w: jnp.ndarray, xd_t: jnp.ndarray) -> tuple:
    """G = X_Eᵀ D(w) X_D — the Algorithm-1 augmentation panel."""
    return (gram_block(xe_t, w, xd_t),)


def logistic_kkt(
    xt: jnp.ndarray,
    y: jnp.ndarray,
    eta: jnp.ndarray,
    lam: jnp.ndarray,
    tp: int = 256,
    tn: int = 256,
) -> tuple:
    """Fused logistic KKT sweep: μ(η) → residual → correlation → mask."""
    mu = 1.0 / (1.0 + jnp.exp(-eta))
    resid = y - mu
    c = xt_r(xt, resid, tp=tp, tn=tn)
    viol = (jnp.abs(c) > lam).astype(xt.dtype)
    return c, resid, viol
