//! Synthetic problem generator — paper §4.1.
//!
//! Rows of X are drawn i.i.d. from N(0, Σ); the response is
//! N(Xβ, σ²I) with σ² = βᵀΣβ / SNR (Gaussian), Bernoulli(σ(xᵀβ))
//! (logistic), or Poisson(exp(xᵀβ)) (App. F.9). `s` coefficients equally
//! spaced throughout β are set to 1 and the rest to 0, exactly as in the
//! paper.

use super::{standardize, Dataset, DesignMatrix};
use crate::linalg::{CscMatrix, DenseMatrix};
use crate::loss::Loss;
use crate::rng::{GaussianSource, Xoshiro256pp};

/// Correlation structure of the simulated design.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CorrelationStructure {
    /// Σ = ρ11ᵀ + (1−ρ)I — the paper's §4.1 setup.
    Equicorrelated,
    /// corr(xᵢ, xⱼ) = ρ^|i−j|.
    Ar1,
    /// ρ within contiguous blocks of the given size, 0 across.
    Block(usize),
}

/// Builder for synthetic problems.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub n: usize,
    pub p: usize,
    pub s: usize,
    pub rho: f64,
    pub snr: f64,
    pub loss: Loss,
    pub structure: CorrelationStructure,
    pub seed: u64,
    /// If Some(d), generate a sparse design with approximate density d
    /// (entries present i.i.d. with prob. d; values N(0,1); correlation
    /// structure is ignored for sparse designs).
    pub density: Option<f64>,
    /// Scale applied to the Poisson/logistic linear predictor to keep
    /// the response in a realistic range (β entries are ±1 as in the
    /// paper; for Poisson exp(η) explodes without damping).
    pub signal_scale: f64,
    pub standardize: bool,
}

impl SyntheticSpec {
    pub fn new(n: usize, p: usize, s: usize) -> Self {
        Self {
            n,
            p,
            s,
            rho: 0.0,
            snr: 1.0,
            loss: Loss::Gaussian,
            structure: CorrelationStructure::Equicorrelated,
            seed: 0,
            density: None,
            signal_scale: 1.0,
            standardize: true,
        }
    }

    pub fn rho(mut self, rho: f64) -> Self {
        self.rho = rho;
        self
    }

    pub fn snr(mut self, snr: f64) -> Self {
        self.snr = snr;
        self
    }

    pub fn loss(mut self, loss: Loss) -> Self {
        self.loss = loss;
        self
    }

    pub fn structure(mut self, s: CorrelationStructure) -> Self {
        self.structure = s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn density(mut self, d: f64) -> Self {
        self.density = Some(d);
        self
    }

    pub fn signal_scale(mut self, s: f64) -> Self {
        self.signal_scale = s;
        self
    }

    pub fn standardize(mut self, yes: bool) -> Self {
        self.standardize = yes;
        self
    }

    /// True coefficient vector: `s` ones equally spaced through β.
    pub fn beta_true(&self) -> Vec<f64> {
        let mut beta = vec![0.0; self.p];
        if self.s == 0 {
            return beta;
        }
        let step = (self.p as f64 / self.s as f64).max(1.0);
        for k in 0..self.s {
            let j = ((k as f64 + 0.5) * step).floor() as usize;
            beta[j.min(self.p - 1)] = self.signal_scale;
        }
        beta
    }

    /// βᵀΣβ for the noise calibration σ² = βᵀΣβ/SNR.
    fn signal_variance(&self, beta: &[f64]) -> f64 {
        match self.structure {
            CorrelationStructure::Equicorrelated => {
                let sum: f64 = beta.iter().sum();
                let sq: f64 = beta.iter().map(|b| b * b).sum();
                self.rho * sum * sum + (1.0 - self.rho) * sq
            }
            CorrelationStructure::Ar1 => {
                let nz: Vec<(usize, f64)> = beta
                    .iter()
                    .enumerate()
                    .filter(|(_, b)| **b != 0.0)
                    .map(|(j, &b)| (j, b))
                    .collect();
                let mut s = 0.0;
                for &(i, bi) in &nz {
                    for &(j, bj) in &nz {
                        s += bi * bj * self.rho.powi((i as i32 - j as i32).abs());
                    }
                }
                s
            }
            CorrelationStructure::Block(sz) => {
                let mut s = 0.0;
                let nz: Vec<(usize, f64)> = beta
                    .iter()
                    .enumerate()
                    .filter(|(_, b)| **b != 0.0)
                    .map(|(j, &b)| (j, b))
                    .collect();
                for &(i, bi) in &nz {
                    for &(j, bj) in &nz {
                        let c = if i == j {
                            1.0
                        } else if i / sz == j / sz {
                            self.rho
                        } else {
                            0.0
                        };
                        s += bi * bj * c;
                    }
                }
                s
            }
        }
    }

    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self) -> Dataset {
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        let beta = self.beta_true();
        let mut eta = vec![0.0; self.n];

        let mut design = if let Some(d) = self.density {
            // Sparse design: Bernoulli(d) mask, N(0,1) values.
            let mut triplets = Vec::new();
            for j in 0..self.p {
                for i in 0..self.n {
                    if rng.next_f64() < d {
                        triplets.push((i, j, rng.next_gaussian()));
                    }
                }
            }
            let m = CscMatrix::from_triplets(self.n, self.p, &triplets);
            for i in 0..self.n {
                eta[i] = 0.0;
            }
            for (j, &b) in beta.iter().enumerate() {
                if b != 0.0 {
                    use crate::linalg::Design;
                    m.col_axpy(j, b, &mut eta);
                }
            }
            DesignMatrix::Sparse(m)
        } else {
            let mut m = DenseMatrix::zeros(self.n, self.p);
            let mut row = vec![0.0; self.p];
            for i in 0..self.n {
                {
                    let mut src = GaussianSource::new(&mut rng);
                    match self.structure {
                        CorrelationStructure::Equicorrelated => {
                            src.fill_equicorrelated_row(&mut row, self.rho)
                        }
                        CorrelationStructure::Ar1 => src.fill_ar1_row(&mut row, self.rho),
                        CorrelationStructure::Block(sz) => {
                            src.fill_block_row(&mut row, self.rho, sz)
                        }
                    }
                }
                let mut e = 0.0;
                for j in 0..self.p {
                    *m.at_mut(i, j) = row[j];
                    e += row[j] * beta[j];
                }
                eta[i] = e;
            }
            DesignMatrix::Dense(m)
        };

        let mut y = vec![0.0; self.n];
        match self.loss {
            Loss::Gaussian => {
                let sigma2 = self.signal_variance(&beta) / self.snr;
                let sigma = sigma2.max(0.0).sqrt();
                for i in 0..self.n {
                    y[i] = eta[i] + sigma * rng.next_gaussian();
                }
            }
            Loss::Logistic => {
                for i in 0..self.n {
                    let pr = crate::loss::sigmoid(eta[i]);
                    y[i] = if rng.next_bernoulli(pr) { 1.0 } else { 0.0 };
                }
            }
            Loss::Poisson => {
                for i in 0..self.n {
                    y[i] = rng.next_poisson(eta[i].min(20.0).exp()) as f64;
                }
            }
        }

        if self.standardize {
            standardize(&mut design, &mut y, self.loss);
        }

        Dataset {
            name: format!(
                "synthetic(n={},p={},s={},rho={},{:?})",
                self.n, self.p, self.s, self.rho, self.loss
            ),
            design,
            response: y,
            beta_true: Some(beta),
            loss: self.loss,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Design;

    #[test]
    fn beta_true_spacing() {
        let spec = SyntheticSpec::new(10, 100, 5);
        let b = spec.beta_true();
        let nz: Vec<usize> = (0..100).filter(|&j| b[j] != 0.0).collect();
        assert_eq!(nz.len(), 5);
        // equally spaced: gaps all equal
        let gaps: Vec<usize> = nz.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.iter().all(|&g| g == gaps[0]));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = SyntheticSpec::new(20, 10, 3).seed(7).generate();
        let b = SyntheticSpec::new(20, 10, 3).seed(7).generate();
        let c = SyntheticSpec::new(20, 10, 3).seed(8).generate();
        assert_eq!(a.response, b.response);
        assert_ne!(a.response, c.response);
    }

    #[test]
    fn standardized_dense_design() {
        let d = SyntheticSpec::new(50, 8, 2).rho(0.5).seed(1).generate();
        if let DesignMatrix::Dense(m) = &d.design {
            for j in 0..8 {
                let col = m.col(j);
                let mean: f64 = col.iter().sum::<f64>() / 50.0;
                let ss: f64 = col.iter().map(|v| v * v).sum::<f64>() / 50.0;
                assert!(mean.abs() < 1e-10);
                assert!((ss - 1.0).abs() < 1e-8);
            }
        } else {
            panic!("expected dense");
        }
        // y centered for Gaussian
        assert!(d.response.iter().sum::<f64>().abs() < 1e-8);
    }

    #[test]
    fn logistic_response_binary() {
        let d = SyntheticSpec::new(100, 5, 2)
            .loss(Loss::Logistic)
            .seed(3)
            .generate();
        assert!(d.response.iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = d.response.iter().sum::<f64>();
        assert!(ones > 10.0 && ones < 90.0, "balanced-ish: {ones}");
    }

    #[test]
    fn poisson_response_counts() {
        let d = SyntheticSpec::new(100, 5, 2)
            .loss(Loss::Poisson)
            .signal_scale(0.5)
            .seed(3)
            .generate();
        assert!(d
            .response
            .iter()
            .all(|&v| v >= 0.0 && v.fract() == 0.0));
    }

    #[test]
    fn sparse_design_density() {
        let d = SyntheticSpec::new(100, 50, 5).density(0.05).seed(5).generate();
        assert!(d.design.is_sparse());
        let dens = d.design.density();
        assert!((dens - 0.05).abs() < 0.02, "density {dens}");
    }

    #[test]
    fn snr_controls_noise() {
        // higher SNR => higher correlation between y and eta-direction
        let lo = SyntheticSpec::new(400, 10, 2).snr(0.1).seed(9).standardize(false).generate();
        let hi = SyntheticSpec::new(400, 10, 2).snr(100.0).seed(9).standardize(false).generate();
        let b = SyntheticSpec::new(400, 10, 2).beta_true();
        let corr = |d: &Dataset| {
            let mut eta = vec![0.0; 400];
            if let DesignMatrix::Dense(m) = &d.design {
                for j in 0..10 {
                    m.col_axpy(j, b[j], &mut eta);
                }
            }
            let my = d.response.iter().sum::<f64>() / 400.0;
            let me = eta.iter().sum::<f64>() / 400.0;
            let mut num = 0.0;
            let mut dy = 0.0;
            let mut de = 0.0;
            for i in 0..400 {
                num += (d.response[i] - my) * (eta[i] - me);
                dy += (d.response[i] - my).powi(2);
                de += (eta[i] - me).powi(2);
            }
            num / (dy * de).sqrt()
        };
        assert!(corr(&hi) > 0.99);
        assert!(corr(&lo) < corr(&hi));
    }

    #[test]
    fn signal_variance_formulas() {
        let mut spec = SyntheticSpec::new(10, 6, 2).rho(0.5);
        let beta = vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        // equicorrelated: rho*sum^2 + (1-rho)*sq = 0.5*4 + 0.5*2 = 3
        assert!((spec.signal_variance(&beta) - 3.0).abs() < 1e-12);
        spec.structure = CorrelationStructure::Ar1;
        // ar1: 2 + 2*rho^3 = 2 + 0.25
        assert!((spec.signal_variance(&beta) - 2.25).abs() < 1e-12);
        spec.structure = CorrelationStructure::Block(3);
        // blocks {0,1,2},{3,4,5}: cross-block corr 0 => 2
        assert!((spec.signal_variance(&beta) - 2.0).abs() < 1e-12);
    }
}
