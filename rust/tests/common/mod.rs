//! Shared helpers for the integration suites.
//!
//! `HX_TEST_SHAPE=small` shrinks every suite's problem shapes so slow
//! interpreters (miri, the sanitizer jobs) can run the same tests
//! end-to-end in reasonable time; the defaults stay the CI-native
//! shapes. Each call site picks its own shrunk preset so raggedness
//! properties (p not divisible by the shard counts under test) are
//! preserved at both sizes.

/// Pick `(n, p)` by the `HX_TEST_SHAPE` env knob: `small` selects the
/// shrunk preset, anything else (including unset) the default.
pub fn test_shape(default: (usize, usize), small: (usize, usize)) -> (usize, usize) {
    match std::env::var("HX_TEST_SHAPE") {
        Ok(v) if v.trim().eq_ignore_ascii_case("small") => small,
        _ => default,
    }
}
