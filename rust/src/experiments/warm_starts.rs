//! Figure 2: coordinate-descent passes with Hessian vs. standard warm
//! starts, on the colon-cancer (logistic) and YearPredictionMSD
//! (least-squares) analogues.

use super::*;
use crate::data::dataset_by_name;
use crate::metrics::Table;

pub fn run(cfg: &ExpConfig) -> Result<(), String> {
    let mut table = Table::new(&["Dataset", "Warm start", "Total passes", "Steps", "Time (s)"]);
    let mut series = String::from("dataset,warm,step,passes\n");
    for name in ["colon-cancer", "YearPredictionMSD"] {
        let mut spec = dataset_by_name(name).ok_or("unknown dataset")?;
        if !cfg.full && name == "YearPredictionMSD" {
            spec.n = 20_000; // quick preset
        }
        let data = spec.generate(0);
        for warm in [true, false] {
            let mut settings = paper_settings();
            settings.hessian_warm_starts = warm;
            let (fit, secs) = fit_timed(&data, ScreeningKind::Hessian, &settings);
            table.row(vec![
                name.into(),
                if warm { "Hessian (eq. 7)" } else { "standard" }.into(),
                format!("{}", fit.total_passes()),
                format!("{}", fit.steps.len()),
                crate::metrics::fmt_secs(secs),
            ]);
            for (k, s) in fit.steps.iter().enumerate() {
                series.push_str(&format!("{name},{warm},{k},{}\n", s.passes));
            }
        }
    }
    println!("\nFigure 2 — CD passes: Hessian vs standard warm starts");
    println!("{}", table.render());
    write_csv(cfg, "fig2_warm_starts", &table);
    write_text(cfg, "fig2_series.csv", &series);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hessian_warm_start_no_worse_on_tall_data() {
        // Fig. 2's YearPredictionMSD regime (n ≫ p): Hessian warm starts
        // should cut the pass count substantially.
        let data = simulate(1500, 30, 8, 0.3, 5.0, Loss::Gaussian, 4);
        let mut on = paper_settings();
        on.path_length = 60;
        let mut off = on.clone();
        off.hessian_warm_starts = false;
        let (with_ws, _) = fit_timed(&data, ScreeningKind::Hessian, &on);
        let (without, _) = fit_timed(&data, ScreeningKind::Hessian, &off);
        assert!(
            (with_ws.total_passes() as f64) <= 0.9 * without.total_passes() as f64,
            "warm {} vs standard {}",
            with_ws.total_passes(),
            without.total_passes()
        );
    }
}
